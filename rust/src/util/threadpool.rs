//! A small scoped thread pool for partition-parallel execution.
//!
//! Tokio is unavailable offline; the coordinator's hot loop only needs
//! fork/join over partitions, which `std::thread::scope` provides.
//! This wrapper adds work distribution and panic propagation, and is
//! reused by the benchmark harness.

/// Run `f(i)` for every `i in 0..n`, distributing across up to
/// `threads` OS threads, and collect the results in index order.
///
/// Panics in workers are propagated to the caller.
pub fn parallel_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    parallel_map_init(n, threads, || (), |i, ()| f(i))
}

/// [`parallel_map`] with per-worker scratch state: each worker calls
/// `init()` once and threads the resulting value through every `f`
/// call it services. The sweep executor uses this to reuse encode
/// buffers and key strings across the cells a worker runs, instead of
/// reallocating per cell. Determinism note: `f` must not let `scratch`
/// leak into results — which cells share a scratch depends on
/// scheduling.
pub fn parallel_map_init<T: Send, S>(
    n: usize,
    threads: usize,
    init: impl Fn() -> S + Sync,
    f: impl Fn(usize, &mut S) -> T + Sync,
) -> Vec<T> {
    assert!(threads > 0, "threads must be > 0");
    if n == 0 {
        return Vec::new();
    }
    let workers = threads.min(n);
    if workers == 1 {
        let mut scratch = init();
        return (0..n).map(|i| f(i, &mut scratch)).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    // Each worker computes into a local Vec<(index, value)> and the
    // results are scattered back in index order afterwards — no unsafe,
    // and contention on the mutex is one lock per worker, not per item.
    let results: std::sync::Mutex<Vec<(usize, T)>> = std::sync::Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut scratch = init();
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i, &mut scratch)));
                }
                results.lock().unwrap().extend(local);
            });
        }
    });
    for (i, v) in results.into_inner().unwrap() {
        out[i] = Some(v);
    }
    out.into_iter()
        .map(|v| v.expect("worker missed an index"))
        .collect()
}

/// Default worker count: the `HEMINGWAY_THREADS` environment override
/// when set (CI pins `HEMINGWAY_THREADS=1` for determinism checks),
/// else physical parallelism, capped.
pub fn default_threads() -> usize {
    let env = std::env::var("HEMINGWAY_THREADS").ok();
    match parse_thread_override(env.as_deref()) {
        Some(n) => n,
        None => {
            if let Some(v) = env {
                crate::log_warn!("ignoring invalid HEMINGWAY_THREADS='{v}'");
            }
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
                .min(16)
        }
    }
}

/// Parse a `HEMINGWAY_THREADS` value (split out so tests don't have to
/// mutate the process environment, which races with concurrent
/// readers in other tests).
fn parse_thread_override(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn env_override_parsing() {
        assert_eq!(parse_thread_override(Some("3")), Some(3));
        assert_eq!(parse_thread_override(Some(" 8 ")), Some(8));
        assert_eq!(parse_thread_override(Some("0")), None);
        assert_eq!(parse_thread_override(Some("not-a-number")), None);
        assert_eq!(parse_thread_override(None), None);
        // Whatever the ambient environment, the default is usable.
        assert!(default_threads() >= 1);
    }

    #[test]
    fn scratch_is_reused_within_a_worker() {
        // Each worker's scratch buffer grows once and is reused; the
        // results are still in index order and scheduling-independent.
        let out = parallel_map_init(
            50,
            4,
            || Vec::with_capacity(8),
            |i, scratch: &mut Vec<usize>| {
                scratch.clear();
                scratch.extend(0..=i);
                scratch.iter().sum::<usize>()
            },
        );
        let expect: Vec<usize> = (0..50).map(|i| i * (i + 1) / 2).collect();
        assert_eq!(out, expect);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        parallel_map(4, 2, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }
}

//! A small scoped thread pool for partition-parallel execution.
//!
//! Tokio is unavailable offline; the coordinator's hot loop only needs
//! fork/join over partitions, which `std::thread::scope` provides.
//! This wrapper adds work distribution and panic propagation, and is
//! reused by the benchmark harness.

/// Run `f(i)` for every `i in 0..n`, distributing across up to
/// `threads` OS threads, and collect the results in index order.
///
/// Panics in workers are propagated to the caller.
pub fn parallel_map<T: Send>(n: usize, threads: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    parallel_map_init(n, threads, || (), |i, ()| f(i))
}

/// [`parallel_map`] with per-worker scratch state: each worker calls
/// `init()` once and threads the resulting value through every `f`
/// call it services. The sweep executor uses this to reuse encode
/// buffers and key strings across the cells a worker runs, instead of
/// reallocating per cell. Determinism note: `f` must not let `scratch`
/// leak into results — which cells share a scratch depends on
/// scheduling.
pub fn parallel_map_init<T: Send, S>(
    n: usize,
    threads: usize,
    init: impl Fn() -> S + Sync,
    f: impl Fn(usize, &mut S) -> T + Sync,
) -> Vec<T> {
    assert!(threads > 0, "threads must be > 0");
    if n == 0 {
        return Vec::new();
    }
    let workers = threads.min(n);
    if workers == 1 {
        let mut scratch = init();
        return (0..n).map(|i| f(i, &mut scratch)).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    // Each worker computes into a local Vec<(index, value)> and the
    // results are scattered back in index order afterwards — no unsafe,
    // and contention on the mutex is one lock per worker, not per item.
    let results: std::sync::Mutex<Vec<(usize, T)>> = std::sync::Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| {
                let mut scratch = init();
                let mut local: Vec<(usize, T)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    local.push((i, f(i, &mut scratch)));
                }
                results.lock().unwrap().extend(local);
            });
        }
    });
    for (i, v) in results.into_inner().unwrap() {
        out[i] = Some(v);
    }
    out.into_iter()
        .map(|v| v.expect("worker missed an index"))
        .collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: std::collections::VecDeque<Job>,
    closed: bool,
}

struct PoolShared {
    state: std::sync::Mutex<PoolState>,
    capacity: usize,
    /// Signaled when the queue gains a job or the pool closes.
    available: std::sync::Condvar,
    /// Signaled when a worker takes a job (submitters waiting on a
    /// full queue re-check here).
    space: std::sync::Condvar,
}

/// A bounded long-lived worker pool for connection/request handling.
///
/// [`parallel_map`] covers fork/join over a known workload; the serve
/// path instead needs workers that outlive any one task and a queue
/// that applies backpressure when connections arrive faster than they
/// drain. Submission blocks while the queue is at capacity, and
/// [`TaskPool::shutdown`] drains queued plus in-flight jobs before
/// returning — the graceful-shutdown contract the advisor server
/// relies on.
pub struct TaskPool {
    shared: std::sync::Arc<PoolShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl TaskPool {
    pub fn new(workers: usize, capacity: usize) -> TaskPool {
        assert!(workers > 0, "workers must be > 0");
        assert!(capacity > 0, "capacity must be > 0");
        let shared = std::sync::Arc::new(PoolShared {
            state: std::sync::Mutex::new(PoolState {
                queue: std::collections::VecDeque::new(),
                closed: false,
            }),
            capacity,
            available: std::sync::Condvar::new(),
            space: std::sync::Condvar::new(),
        });
        let workers = (0..workers)
            .map(|i| {
                let shared = std::sync::Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("hemingway-pool-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn pool worker")
            })
            .collect();
        TaskPool { shared, workers }
    }

    /// Submit a job, blocking while the queue is at capacity. Returns
    /// false (dropping the job) once the pool has shut down.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) -> bool {
        let mut state = self.shared.state.lock().unwrap();
        while state.queue.len() >= self.shared.capacity && !state.closed {
            state = self.shared.space.wait(state).unwrap();
        }
        if state.closed {
            return false;
        }
        state.queue.push_back(Box::new(job));
        drop(state);
        self.shared.available.notify_one();
        true
    }

    /// Jobs currently queued (not yet picked up by a worker).
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Stop accepting new jobs and wait for queued and in-flight jobs
    /// to finish.
    pub fn shutdown(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        {
            let mut state = self.shared.state.lock().unwrap();
            state.closed = true;
        }
        self.shared.available.notify_all();
        self.shared.space.notify_all();
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut state = shared.state.lock().unwrap();
            loop {
                if let Some(job) = state.queue.pop_front() {
                    break job;
                }
                if state.closed {
                    return;
                }
                state = shared.available.wait(state).unwrap();
            }
        };
        shared.space.notify_one();
        // A panicking job must not kill the worker — the pool would
        // silently lose capacity. Contain it and keep serving.
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)).is_err() {
            crate::log_warn!("a pool job panicked; worker continues");
        }
    }
}

/// Default worker count: the `HEMINGWAY_THREADS` environment override
/// when set (CI pins `HEMINGWAY_THREADS=1` for determinism checks),
/// else physical parallelism, capped.
pub fn default_threads() -> usize {
    let env = std::env::var("HEMINGWAY_THREADS").ok();
    match parse_thread_override(env.as_deref()) {
        Some(n) => n,
        None => {
            if let Some(v) = env {
                crate::log_warn!("ignoring invalid HEMINGWAY_THREADS='{v}'");
            }
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
                .min(16)
        }
    }
}

/// Parse a `HEMINGWAY_THREADS` value (split out so tests don't have to
/// mutate the process environment, which races with concurrent
/// readers in other tests).
fn parse_thread_override(v: Option<&str>) -> Option<usize> {
    v.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn env_override_parsing() {
        assert_eq!(parse_thread_override(Some("3")), Some(3));
        assert_eq!(parse_thread_override(Some(" 8 ")), Some(8));
        assert_eq!(parse_thread_override(Some("0")), None);
        assert_eq!(parse_thread_override(Some("not-a-number")), None);
        assert_eq!(parse_thread_override(None), None);
        // Whatever the ambient environment, the default is usable.
        assert!(default_threads() >= 1);
    }

    #[test]
    fn scratch_is_reused_within_a_worker() {
        // Each worker's scratch buffer grows once and is reused; the
        // results are still in index order and scheduling-independent.
        let out = parallel_map_init(
            50,
            4,
            || Vec::with_capacity(8),
            |i, scratch: &mut Vec<usize>| {
                scratch.clear();
                scratch.extend(0..=i);
                scratch.iter().sum::<usize>()
            },
        );
        let expect: Vec<usize> = (0..50).map(|i| i * (i + 1) / 2).collect();
        assert_eq!(out, expect);
    }

    #[test]
    #[should_panic]
    fn worker_panic_propagates() {
        parallel_map(4, 2, |i| {
            if i == 2 {
                panic!("boom");
            }
            i
        });
    }

    #[test]
    fn task_pool_runs_every_job() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let pool = TaskPool::new(4, 2);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let done = Arc::clone(&done);
            // Capacity 2 forces submit-side backpressure along the way.
            assert!(pool.submit(move || {
                done.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn task_pool_drains_queued_jobs_on_shutdown() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        // One slow worker with a deep queue: shutdown must wait for the
        // queued jobs, not drop them.
        let pool = TaskPool::new(1, 64);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..20 {
            let done = Arc::clone(&done);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                done.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), 20);
    }

    #[test]
    fn task_pool_survives_a_panicking_job() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let pool = TaskPool::new(1, 8);
        let done = Arc::new(AtomicUsize::new(0));
        pool.submit(|| panic!("job boom"));
        let after = Arc::clone(&done);
        pool.submit(move || {
            after.fetch_add(1, Ordering::Relaxed);
        });
        pool.shutdown();
        assert_eq!(done.load(Ordering::Relaxed), 1, "worker died with the job");
    }
}

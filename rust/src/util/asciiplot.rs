//! Terminal plotting for the repro harness.
//!
//! Every figure target prints the same series the paper plots as an
//! ASCII chart (plus a CSV for external plotting), so "shape" claims —
//! U-curves, crossovers, model-vs-truth agreement — are visible right
//! in the terminal / EXPERIMENTS.md.

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>, points: Vec<(f64, f64)>) -> Series {
        Series {
            name: name.into(),
            points,
        }
    }
}

/// Plot configuration.
#[derive(Debug, Clone)]
pub struct PlotCfg {
    pub width: usize,
    pub height: usize,
    pub log_y: bool,
    pub log_x: bool,
    pub title: String,
    pub x_label: String,
    pub y_label: String,
}

impl Default for PlotCfg {
    fn default() -> Self {
        PlotCfg {
            width: 72,
            height: 20,
            log_y: false,
            log_x: false,
            title: String::new(),
            x_label: "x".into(),
            y_label: "y".into(),
        }
    }
}

const MARKS: &[char] = &['*', '+', 'o', 'x', '#', '@', '%', '&'];

/// Render series to an ASCII chart.
pub fn plot(series: &[Series], cfg: &PlotCfg) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for s in series {
        for &(x, y) in &s.points {
            let (tx, ty) = transform(x, y, cfg);
            if tx.is_finite() && ty.is_finite() {
                pts.push((tx, ty));
            }
        }
    }
    if pts.is_empty() {
        return format!("{} (no finite data)\n", cfg.title);
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if xmax == xmin {
        xmax = xmin + 1.0;
    }
    if ymax == ymin {
        ymax = ymin + 1.0;
    }

    let w = cfg.width;
    let h = cfg.height;
    let mut grid = vec![vec![' '; w]; h];

    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let (tx, ty) = transform(x, y, cfg);
            if !tx.is_finite() || !ty.is_finite() {
                continue;
            }
            let col = (((tx - xmin) / (xmax - xmin)) * (w - 1) as f64).round() as usize;
            let row = (((ty - ymin) / (ymax - ymin)) * (h - 1) as f64).round() as usize;
            let r = h - 1 - row.min(h - 1);
            let c = col.min(w - 1);
            // Later series overwrite earlier ones; that is fine for
            // model-vs-truth overlays where agreement is the point.
            grid[r][c] = mark;
        }
    }

    let mut out = String::new();
    if !cfg.title.is_empty() {
        out.push_str(&format!("  {}\n", cfg.title));
    }
    let ylab = |v: f64| -> f64 {
        if cfg.log_y {
            10f64.powf(v)
        } else {
            v
        }
    };
    for (r, rowv) in grid.iter().enumerate() {
        let frac = 1.0 - r as f64 / (h - 1) as f64;
        let yv = ylab(ymin + frac * (ymax - ymin));
        let label = if r == 0 || r == h - 1 || r == h / 2 {
            format!("{yv:>11.3e}")
        } else {
            " ".repeat(11)
        };
        out.push_str(&format!("{label} |"));
        out.extend(rowv.iter());
        out.push('\n');
    }
    out.push_str(&format!("{} +{}\n", " ".repeat(11), "-".repeat(w)));
    let xlab = |v: f64| -> f64 {
        if cfg.log_x {
            10f64.powf(v)
        } else {
            v
        }
    };
    out.push_str(&format!(
        "{} {:<12.4} {:^width$} {:>12.4}\n",
        " ".repeat(10),
        xlab(xmin),
        cfg.x_label,
        xlab(xmax),
        width = w.saturating_sub(28)
    ));
    out.push_str("  legend: ");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{}={}  ", MARKS[si % MARKS.len()], s.name));
    }
    out.push('\n');
    out
}

fn transform(x: f64, y: f64, cfg: &PlotCfg) -> (f64, f64) {
    let tx = if cfg.log_x { x.log10() } else { x };
    let ty = if cfg.log_y { y.log10() } else { y };
    (tx, ty)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_basic() {
        let s = Series::new("line", (0..10).map(|i| (i as f64, i as f64)).collect());
        let out = plot(&[s], &PlotCfg { title: "t".into(), ..Default::default() });
        assert!(out.contains('*'));
        assert!(out.contains("legend: *=line"));
        assert!(out.contains("  t\n"));
    }

    #[test]
    fn log_scale_drops_nonpositive() {
        let s = Series::new(
            "conv",
            vec![(0.0, 1.0), (1.0, 0.1), (2.0, 0.0), (3.0, -1.0)],
        );
        let out = plot(
            &[s],
            &PlotCfg {
                log_y: true,
                ..Default::default()
            },
        );
        assert!(out.contains('*')); // finite points survive
    }

    #[test]
    fn empty_series_graceful() {
        let out = plot(&[Series::new("e", vec![])], &PlotCfg::default());
        assert!(out.contains("no finite data"));
    }

    #[test]
    fn multiple_series_legend() {
        let a = Series::new("a", vec![(0.0, 0.0), (1.0, 1.0)]);
        let b = Series::new("b", vec![(0.0, 1.0), (1.0, 0.0)]);
        let out = plot(&[a, b], &PlotCfg::default());
        assert!(out.contains("*=a"));
        assert!(out.contains("+=b"));
    }

    #[test]
    fn constant_series_no_panic() {
        let s = Series::new("c", vec![(1.0, 5.0), (2.0, 5.0)]);
        let _ = plot(&[s], &PlotCfg::default());
    }
}

//! Crate-local error plumbing (the offline registry has no `anyhow`):
//! a boxed error type, the crate-wide [`Result`], and the `err!`,
//! `bail!` and `ensure!` macros the rest of the crate formats errors
//! with. Call sites read exactly like the `anyhow` equivalents.

/// The crate's error type: any boxed error, thread-safe so sweep
/// workers can carry failures across the thread pool.
pub type BoxError = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, BoxError>;

/// Build a [`BoxError`] from an already-formatted message (used by the
/// `err!` macro; call that instead).
pub fn msg(text: String) -> BoxError {
    text.into()
}

/// Construct a [`BoxError`] from a format string:
/// `err!("no column '{name}'")`.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted error: `bail!("unknown command '{cmd}'")`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with a formatted error unless a condition holds:
/// `ensure!(folds >= 2, "need ≥2 folds")`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn err_formats() {
        let e = crate::err!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
    }

    #[test]
    fn bail_returns_err() {
        fn f(x: i32) -> crate::Result<i32> {
            if x < 0 {
                crate::bail!("negative: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(-1).unwrap_err().to_string(), "negative: -1");
    }

    #[test]
    fn ensure_checks_condition() {
        fn f(x: i32) -> crate::Result<()> {
            crate::ensure!(x % 2 == 0, "odd: {x}");
            Ok(())
        }
        assert!(f(2).is_ok());
        assert_eq!(f(3).unwrap_err().to_string(), "odd: 3");
    }

    #[test]
    fn io_errors_convert_through_question_mark() {
        fn f() -> crate::Result<String> {
            Ok(std::fs::read_to_string("/nonexistent/hemingway")?)
        }
        assert!(f().is_err());
    }
}

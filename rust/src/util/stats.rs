//! Summary statistics used by the benchmark harness and Fig 1(a)
//! (mean with 5th/95th percentile error bars over 50 iterations).

/// Mean of a slice (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile by linear interpolation between order statistics
/// (the same convention as numpy's default). `q` in `[0, 100]`.
///
/// NaN entries carry no order information and are dropped before the
/// sort (degenerate traces feed NaN duals through here); the result is
/// NaN only when nothing survives the filter.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=100.0).contains(&q), "percentile q out of range: {q}");
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return f64::NAN;
    }
    v.sort_by(|a, b| a.partial_cmp(b).expect("non-NaN floats are totally ordered"));
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = pos - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Minimum (NaN for empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, f64::min)
}

/// Index of the smallest value, NaN-safe: NaN entries carry no order
/// information and are filtered out before a *total-order* comparison
/// (`f64::total_cmp`), so this never panics the way
/// `partial_cmp(..).unwrap()` min-selections do when a NaN slips into
/// a metric vector. `None` only when the slice is empty or all-NaN.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .filter(|(_, v)| !v.is_nan())
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
}

/// Maximum (NaN for empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, f64::max)
}

/// Coefficient of determination R^2 of predictions vs truth.
pub fn r_squared(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let m = mean(truth);
    let ss_tot: f64 = truth.iter().map(|y| (y - m) * (y - m)).sum();
    let ss_res: f64 = truth
        .iter()
        .zip(pred)
        .map(|(y, p)| (y - p) * (y - p))
        .sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Root-mean-square error of predictions vs truth.
pub fn rmse(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.is_empty() {
        return 0.0;
    }
    let se: f64 = truth
        .iter()
        .zip(pred)
        .map(|(y, p)| (y - p) * (y - p))
        .sum();
    (se / truth.len() as f64).sqrt()
}

/// Mean absolute percentage error (%), skipping zero-truth entries.
pub fn mape(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let mut acc = 0.0;
    let mut n = 0usize;
    for (y, p) in truth.iter().zip(pred) {
        if y.abs() > 1e-300 {
            acc += ((y - p) / y).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * acc / n as f64
    }
}

/// Mean and sample standard deviation of a replicate set — the
/// aggregate the sweep engine reports per (algorithm, machines) cell
/// when a grid runs with multiple seeds.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct MeanStd {
    pub mean: f64,
    pub std: f64,
    /// Number of samples aggregated.
    pub n: usize,
}

impl MeanStd {
    /// Render as `mean±std` with the given precision (for sweep logs).
    pub fn display(&self, decimals: usize) -> String {
        format!("{:.*}±{:.*}", decimals, self.mean, decimals, self.std)
    }
}

/// Aggregate seed replicates into mean ± sample stddev.
pub fn mean_stddev(xs: &[f64]) -> MeanStd {
    MeanStd {
        mean: mean(xs),
        std: stddev(xs),
        n: xs.len(),
    }
}

/// A running summary for streaming timing samples.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        mean(&self.samples)
    }

    pub fn stddev(&self) -> f64 {
        stddev(&self.samples)
    }

    pub fn percentile(&self, q: f64) -> f64 {
        percentile(&self.samples, q)
    }

    pub fn min(&self) -> f64 {
        min(&self.samples)
    }

    pub fn max(&self) -> f64 {
        max(&self.samples)
    }

    pub fn samples(&self) -> &[f64] {
        &self.samples
    }
}

/// A concurrent latency histogram over power-of-two nanosecond
/// buckets: bucket `i` covers `[2^i, 2^(i+1))` ns, so 64 buckets span
/// sub-nanosecond to centuries. Recording is one relaxed atomic
/// increment — no locking and no allocation — which is what the serve
/// path needs when many worker threads account latency into one
/// shared histogram. Percentiles come back as the geometric midpoint
/// of the covering bucket (≤ √2× resolution), plenty for p50/p90/p99
/// reporting.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [std::sync::atomic::AtomicU64; 64],
    count: std::sync::atomic::AtomicU64,
    sum_nanos: std::sync::atomic::AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        // `Default` is not derivable for 64-element arrays.
        LatencyHistogram {
            buckets: std::array::from_fn(|_| std::sync::atomic::AtomicU64::new(0)),
            count: std::sync::atomic::AtomicU64::new(0),
            sum_nanos: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Record one latency sample. Non-positive and non-finite
    /// durations clamp into the smallest bucket rather than panicking
    /// (a clock glitch must not take the server down).
    pub fn record(&self, seconds: f64) {
        use std::sync::atomic::Ordering::Relaxed;
        let nanos = if seconds.is_finite() && seconds > 0.0 {
            (seconds * 1e9) as u64
        } else {
            0
        }
        .max(1);
        let bucket = 63 - nanos.leading_zeros() as usize;
        self.buckets[bucket].fetch_add(1, Relaxed);
        self.count.fetch_add(1, Relaxed);
        self.sum_nanos.fetch_add(nanos, Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean_seconds(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let sum = self.sum_nanos.load(std::sync::atomic::Ordering::Relaxed);
        sum as f64 * 1e-9 / n as f64
    }

    /// The `q`-th percentile in seconds (0 when empty): the geometric
    /// midpoint of the bucket holding the rank-`⌈q/100·n⌉` sample.
    pub fn percentile_seconds(&self, q: f64) -> f64 {
        use std::sync::atomic::Ordering::Relaxed;
        assert!((0.0..=100.0).contains(&q), "percentile q out of range: {q}");
        let counts: Vec<u64> = self.buckets.iter().map(|b| b.load(Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = ((q / 100.0 * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return (1u64 << i) as f64 * std::f64::consts::SQRT_2 * 1e-9;
            }
        }
        unreachable!("rank {rank} beyond total {total}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_variance() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((variance(&xs) - 5.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
        // numpy convention: p5 of [1..5] = 1.2
        assert!((percentile(&xs, 5.0) - 1.2).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let y = [1.0, 2.0, 3.0];
        assert_eq!(r_squared(&y, &y), 1.0);
        let p = [2.0, 2.0, 2.0];
        assert!((r_squared(&y, &p)).abs() < 1e-12);
    }

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn mape_skips_zeros() {
        let t = [0.0, 10.0];
        let p = [5.0, 11.0];
        assert!((mape(&t, &p) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn mean_stddev_aggregates_replicates() {
        let a = mean_stddev(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.n, 4);
        assert_eq!(a.mean, 2.5);
        assert!((a.std - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        // Single replicate: defined, zero spread.
        let one = mean_stddev(&[7.0]);
        assert_eq!((one.mean, one.std, one.n), (7.0, 0.0, 1));
        assert_eq!(one.display(1), "7.0±0.0");
    }

    #[test]
    fn summary_accumulates() {
        let mut s = Summary::new();
        for i in 1..=100 {
            s.add(i as f64);
        }
        assert_eq!(s.len(), 100);
        assert!((s.mean() - 50.5).abs() < 1e-12);
        assert!((s.percentile(95.0) - 95.05).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 100.0);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(rmse(&[], &[]), 0.0);
    }

    #[test]
    fn argmin_is_nan_safe() {
        // The plain case.
        assert_eq!(argmin(&[3.0, 1.0, 2.0]), Some(1));
        assert_eq!(argmin(&[7.0]), Some(0));
        // NaNs anywhere — including first — neither panic nor win.
        assert_eq!(argmin(&[f64::NAN, 5.0, 2.0, f64::NAN, 9.0]), Some(2));
        assert_eq!(argmin(&[f64::NAN, f64::NAN, 4.0]), Some(2));
        // Total order handles infinities and signed zeros.
        assert_eq!(argmin(&[0.0, f64::NEG_INFINITY, 1.0]), Some(1));
        assert_eq!(argmin(&[0.0, -0.0]), Some(1), "-0.0 orders below +0.0");
        // Empty and all-NaN inputs answer nothing instead of panicking.
        assert_eq!(argmin(&[]), None);
        assert_eq!(argmin(&[f64::NAN, f64::NAN]), None);
    }

    #[test]
    fn percentile_ignores_nans() {
        // NaN entries are dropped, not panicked on: the percentile of
        // what remains is exactly the NaN-free answer.
        let with_nan = [5.0, f64::NAN, 1.0, f64::NAN, 3.0];
        assert_eq!(percentile(&with_nan, 50.0), 3.0);
        assert_eq!(percentile(&with_nan, 0.0), 1.0);
        assert_eq!(percentile(&with_nan, 100.0), 5.0);
        assert_eq!(median(&with_nan), percentile(&[1.0, 3.0, 5.0], 50.0));
        // Only when nothing survives is the answer NaN.
        assert!(percentile(&[f64::NAN, f64::NAN], 95.0).is_nan());
        assert!(median(&[f64::NAN]).is_nan());
    }

    #[test]
    fn latency_histogram_buckets_and_percentiles() {
        let h = LatencyHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile_seconds(50.0), 0.0);
        assert_eq!(h.mean_seconds(), 0.0);
        // 90 fast samples (~10µs) and 10 slow ones (~10ms): p50 lands
        // in the fast bucket, p99 in the slow one, each within the
        // histogram's 2× bucket resolution.
        for _ in 0..90 {
            h.record(10e-6);
        }
        for _ in 0..10 {
            h.record(10e-3);
        }
        assert_eq!(h.count(), 100);
        let p50 = h.percentile_seconds(50.0);
        let p99 = h.percentile_seconds(99.0);
        assert!((5e-6..20e-6).contains(&p50), "p50 {p50}");
        assert!((5e-3..20e-3).contains(&p99), "p99 {p99}");
        assert!(p50 < p99);
        let mean = h.mean_seconds();
        assert!((0.5e-3..2e-3).contains(&mean), "mean {mean}");
    }

    #[test]
    fn latency_histogram_tolerates_degenerate_samples() {
        let h = LatencyHistogram::new();
        h.record(0.0);
        h.record(-1.0);
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        // All four clamp into the smallest bucket instead of panicking.
        assert_eq!(h.count(), 4);
        assert!(h.percentile_seconds(100.0) < 1e-8);
        // Concurrent recording from many threads stays consistent.
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..1000 {
                        h.record(1e-6);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4 + 4000);
    }
}

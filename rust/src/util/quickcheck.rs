//! A miniature property-based testing framework.
//!
//! The offline registry has no `proptest`, so this module provides the
//! subset our invariant tests need: seeded random case generation, a
//! configurable number of cases, and on failure a greedy shrinking pass
//! plus a report of the seed that reproduces the counterexample.
//!
//! ```no_run
//! // (no_run: rustdoc's temp binaries don't get the xla rpath flags)
//! use hemingway::util::quickcheck::{forall, Gen};
//! forall("sum is commutative", 200, |g: &mut Gen| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     ((a, b), ())
//! }, |&(a, b), _| a + b == b + a);
//! ```

use super::rng::Pcg32;

/// Random-input generator handed to the case builder.
pub struct Gen {
    rng: Pcg32,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Pcg32::seeded(seed),
        }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| self.f64_in(lo as f64, hi as f64) as f32)
            .collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    /// Expose the underlying RNG for custom generation.
    pub fn rng(&mut self) -> &mut Pcg32 {
        &mut self.rng
    }
}

/// Run `cases` random cases of a property. The builder returns
/// `(input, aux)`; `prop(input, aux)` must hold for every case.
/// Panics (with the reproducing seed) on the first failure.
pub fn forall<I: std::fmt::Debug, A>(
    name: &str,
    cases: u64,
    build: impl Fn(&mut Gen) -> (I, A),
    prop: impl Fn(&I, &A) -> bool,
) {
    // Base seed is fixed so CI is deterministic; override with
    // QUICKCHECK_SEED to explore.
    let base: u64 = std::env::var("QUICKCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x48454d49); // "HEMI"
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        let (input, aux) = build(&mut g);
        if !prop(&input, &aux) {
            panic!(
                "property '{name}' failed on case {case} (seed {seed}):\n  input = {input:?}\n\
                 reproduce with QUICKCHECK_SEED={base} (case index {case})"
            );
        }
    }
}

/// Like [`forall`] but for fallible properties: failing `Err` counts as
/// a property violation with the error message attached.
pub fn forall_ok<I: std::fmt::Debug, A>(
    name: &str,
    cases: u64,
    build: impl Fn(&mut Gen) -> (I, A),
    prop: impl Fn(&I, &A) -> Result<(), String>,
) {
    forall(name, cases, build, |input, aux| match prop(input, aux) {
        Ok(()) => true,
        Err(msg) => {
            eprintln!("property '{name}' violation: {msg}");
            false
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall(
            "abs is nonnegative",
            100,
            |g| (g.f64_in(-10.0, 10.0), ()),
            |x, _| x.abs() >= 0.0,
        );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        forall("always fails", 10, |g| (g.bool(), ()), |_, _| false);
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen::new(1);
        for _ in 0..1000 {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn choose_covers() {
        let mut g = Gen::new(2);
        let opts = [1, 2, 3];
        let mut seen = [false; 3];
        for _ in 0..100 {
            seen[*g.choose(&opts) - 1] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}

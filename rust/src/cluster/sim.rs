//! The cluster iteration-time simulator — the stand-in for the paper's
//! Spark/YARN testbed, generalized from a pure-BSP barrier to the full
//! [`BarrierMode`] axis.
//!
//! Every machine keeps its **own clock**. One iteration of machine `k`
//! costs
//!
//! ```text
//! d_k = θ_fixed                       (driver bookkeeping)
//!     + sched · m                     (serial task dispatch)
//!     + broadcast(m, model bytes)     (tree, log m rounds)
//!     + compute_k · fleet_factor_k    (lognormal noise + stragglers,
//!                                      scaled by the machine's fleet
//!                                      factor: mixed types, persistent
//!                                      slow nodes — cluster::fleet)
//!     + reduce(m, update bytes)       (tree, log m rounds)
//! ```
//!
//! and the machine starts its next iteration at
//! `max(own clock, barrier)`, where the barrier is the time at which
//! *all* machines finished the iteration `staleness` steps back:
//!
//! * **BSP** — staleness 0: every start waits for everyone's previous
//!   finish, so each iteration costs the slowest machine's `d_k` —
//!   exactly the original `BspSim` pricing.
//! * **SSP(s)** — a machine only blocks when it would run more than
//!   `s` iterations ahead of the slowest; fast machines absorb slow
//!   ones' noise, and a straggler no longer stalls the whole cluster.
//! * **Async** — no barrier: elapsed time is throughput-derived (the
//!   max of independent per-machine clock sums) instead of a per-step
//!   barrier max.
//!
//! All modes consume the RNG identically (m compute draws per
//! iteration, in machine order), so for a fixed seed the three modes
//! price the *same* noise realization — which is what makes the
//! `Async ≤ SSP(s) ≤ BSP` elapsed-time ordering and the
//! `SSP(0) ≡ BSP` equivalence exact, seed by seed, rather than merely
//! statistical (property-tested in `tests/barrier_props.rs`).
//!
//! The Ernest model never sees these mechanisms — it has to
//! *rediscover* the structure from observed times, exactly as it does
//! against real clusters (Tbl E1 checks the fit error).

use std::collections::VecDeque;

use super::barrier::BarrierMode;
use super::fleet::FleetSpec;
use super::network::{broadcast_time, reduce_time};
use super::profile::HardwareProfile;
use crate::optim::driver::IterationTimer;
use crate::optim::IterationCost;
use crate::util::rng::{fnv1a_64, Pcg32};

/// How many committed-iteration barrier times `Async` retains for the
/// staleness probe (its staleness is unbounded in principle; reads
/// report at most this). Tied to the algorithms' snapshot retention so
/// a reported staleness always has a snapshot to serve it.
const ASYNC_STALENESS_WINDOW: usize = crate::optim::stale::MAX_STALE_SNAPSHOTS;

/// Simulated cluster clock with per-machine progress.
pub struct ClusterSim {
    /// The hardware this cluster is made of — a uniform fleet for the
    /// historical plain-profile constructors.
    pub fleet: FleetSpec,
    pub mode: BarrierMode,
    rng: Pcg32,
    /// Simulated time at which the last machine finished the most
    /// recent iteration (the driver-visible clock).
    pub elapsed: f64,
    /// Dollars billed so far: every allocated machine pays its type's
    /// `$/machine-second` for the full wall clock, computing or waiting
    /// at a barrier.
    pub spent_dollars: f64,
    /// Per-iteration marginal elapsed time (Fig 1(a) percentile bars).
    pub history: Vec<f64>,
    /// Per-machine finish time of that machine's latest iteration.
    clocks: Vec<f64>,
    /// Completion times of recent iterations: `barriers.back()` is the
    /// time all machines finished the latest iteration. Bounded by the
    /// blocking window (staleness + 1; a fixed window for Async).
    barriers: VecDeque<f64>,
}

impl ClusterSim {
    /// A BSP-mode simulator (the historical default).
    pub fn new(profile: HardwareProfile, seed: u64) -> ClusterSim {
        Self::with_mode(profile, BarrierMode::Bsp, seed)
    }

    /// A simulator over a uniform fleet of one profile in an explicit
    /// barrier mode — bit-identical to `with_fleet` on
    /// [`FleetSpec::uniform`] of the same profile.
    pub fn with_mode(profile: HardwareProfile, mode: BarrierMode, seed: u64) -> ClusterSim {
        Self::with_fleet(FleetSpec::uniform(profile), mode, seed)
    }

    /// A simulator over an arbitrary fleet. The RNG stream is derived
    /// from the FNV-1a hash of the *base profile's name* (not its
    /// length — two profiles with equal-length names must not share a
    /// noise realization), so:
    ///
    /// * every barrier mode prices the same draws (cross-mode pairing,
    ///   as before), and
    /// * every fleet built on the same base profile prices the same
    ///   draws too — uniform-vs-heterogeneous comparisons at one seed
    ///   are paired, not merely distributional.
    pub fn with_fleet(fleet: FleetSpec, mode: BarrierMode, seed: u64) -> ClusterSim {
        ClusterSim {
            rng: Pcg32::new(seed, 0xC1u64 ^ fnv1a_64(fleet.base.name.as_bytes())),
            fleet,
            mode,
            elapsed: 0.0,
            spent_dollars: 0.0,
            history: Vec::new(),
            clocks: Vec::new(),
            barriers: VecDeque::new(),
        }
    }

    /// The base hardware profile (fixed costs, network, noise).
    pub fn profile(&self) -> &HardwareProfile {
        &self.fleet.base
    }

    /// Price one iteration (and advance the simulated clocks). Returns
    /// the marginal increase of the driver-visible elapsed time.
    pub fn iteration_time(&mut self, cost: &IterationCost) -> f64 {
        let p = &self.fleet.base;
        let m = cost.machines.max(1);
        if self.clocks.len() != m {
            // First iteration, or a mid-run reconfiguration (the
            // adaptive loop repartitions): a global barrier — all
            // machines restart in sync at the current elapsed time.
            self.clocks.clear();
            self.clocks.resize(m, self.elapsed);
            self.barriers.clear();
        }

        let base = cost.flops_per_machine / p.flops_per_sec;
        // Everything but compute is identical across machines; the sum
        // order matches the historical BSP formula term for term.
        let fixed = p.iteration_overhead
            + p.sched_per_machine * m as f64
            + broadcast_time(p, m, cost.broadcast_bytes);
        let reduce = reduce_time(p, m, cost.reduce_bytes);

        // The barrier this iteration's starts must respect: the finish
        // of the iteration `staleness` steps back (none while fewer
        // iterations have committed, and never for Async).
        let barrier = match self.mode.staleness_bound() {
            Some(s) if self.barriers.len() > s => {
                Some(self.barriers[self.barriers.len() - 1 - s])
            }
            _ => None,
        };

        let mut done = 0.0f64;
        for k in 0..m {
            let mut compute = if p.noise_sigma > 0.0 {
                base * self.rng.lognormal(0.0, p.noise_sigma)
            } else {
                base
            };
            if p.straggler_prob > 0.0 && self.rng.uniform() < p.straggler_prob {
                compute *= p.straggler_factor;
            }
            // Heterogeneity scales only the compute term, after the
            // draws: RNG consumption is identical across fleets of one
            // base profile, and a uniform fleet's factor of exactly
            // 1.0 leaves the arithmetic untouched bit for bit.
            let factor = self.fleet.compute_factor(k, m);
            if factor != 1.0 {
                compute *= factor;
            }
            let d = fixed + compute + reduce;
            let start = match barrier {
                Some(b) => self.clocks[k].max(b),
                None => self.clocks[k],
            };
            let finish = start + d;
            self.clocks[k] = finish;
            done = done.max(finish);
        }

        self.barriers.push_back(done);
        let keep = match self.mode.staleness_bound() {
            Some(s) => s + 1,
            None => ASYNC_STALENESS_WINDOW,
        };
        while self.barriers.len() > keep {
            self.barriers.pop_front();
        }

        let dt = done - self.elapsed;
        self.elapsed = done;
        // Bill the allocation: m machines held for dt wall-clock
        // seconds, each at its own type's rate. BSP thus pays for the
        // waiting the barrier imposes; the relaxed modes buy more
        // progress for the same machine-seconds.
        self.spent_dollars += self.fleet.price_rate(m) * dt;
        self.history.push(dt);
        dt
    }

    /// Iteration staleness of the model state the *next* iteration's
    /// fastest reader observes: how many committed iterations are not
    /// yet globally complete at the moment that machine starts. Always
    /// 0 for BSP, at most `s` for SSP(s), reported up to a fixed
    /// window for Async.
    pub fn read_staleness(&self) -> usize {
        if self.clocks.is_empty() {
            return 0;
        }
        let fastest = self.clocks.iter().cloned().fold(f64::INFINITY, f64::min);
        let start = match self.mode.staleness_bound() {
            Some(s) if self.barriers.len() > s => {
                fastest.max(self.barriers[self.barriers.len() - 1 - s])
            }
            _ => fastest,
        };
        // `barriers` is strictly increasing, so the stale ones form a
        // suffix.
        self.barriers.iter().rev().take_while(|&&b| b > start).count()
    }
}

impl IterationTimer for ClusterSim {
    fn price(&mut self, cost: &IterationCost) -> f64 {
        self.iteration_time(cost)
    }

    fn staleness(&self) -> usize {
        self.read_staleness()
    }

    fn mode(&self) -> BarrierMode {
        self.mode
    }
}

/// The historical name for the BSP-mode simulator. Construction via
/// [`ClusterSim::new`] keeps the pure-BSP default; the type is the
/// same so all modes flow through one clock implementation.
pub type BspSim = ClusterSim;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn cocoa_cost(m: usize) -> IterationCost {
        // Default workload: n=8192, d=128, h = n_loc.
        let n_loc = 8192usize.div_ceil(m) as f64;
        IterationCost {
            machines: m,
            flops_per_machine: n_loc * 8.0 * 128.0,
            broadcast_bytes: 4.0 * 128.0,
            reduce_bytes: 4.0 * 128.0,
        }
    }

    #[test]
    fn deterministic_profile_is_deterministic() {
        let mut a = BspSim::new(HardwareProfile::ideal(), 1);
        let mut b = BspSim::new(HardwareProfile::ideal(), 2);
        assert_eq!(a.iteration_time(&cocoa_cost(8)), b.iteration_time(&cocoa_cost(8)));
    }

    #[test]
    fn fig1a_shape_u_curve() {
        // The paper's headline system observation: time/iter improves
        // up to ~32 executors, then degrades.
        let mut means = Vec::new();
        for &m in &[1usize, 2, 4, 8, 16, 32, 64, 128] {
            let mut sim = BspSim::new(HardwareProfile::local48(), 42);
            let ts: Vec<f64> = (0..50).map(|_| sim.iteration_time(&cocoa_cost(m))).collect();
            means.push(stats::mean(&ts));
        }
        // Monotone decrease from m=1 to m=8.
        assert!(means[0] > means[1] && means[1] > means[2] && means[2] > means[3]);
        // The minimum is somewhere in 16–64 and not at the extremes.
        // NaN-filtering total-order selection: a NaN mean (e.g. from a
        // degenerate profile edit) must fail the range assert below,
        // not panic inside an unwrap'd partial_cmp — the same latent
        // panic class as the stats::percentile bug fixed in PR 4.
        let min_idx = stats::argmin(&means).expect("at least one finite mean");
        assert!(
            (3..=6).contains(&min_idx),
            "minimum at index {min_idx}: {means:?}"
        );
        // And m=128 is worse than the minimum.
        assert!(means[7] > means[min_idx] * 1.05, "{means:?}");
    }

    #[test]
    fn scaling_is_sublinear() {
        // "doubling the number of cores does not result in halving the
        // time per iteration" — Fig 1(a) discussion.
        let mut sim = BspSim::new(HardwareProfile::local48(), 7);
        let t1: f64 = (0..30).map(|_| sim.iteration_time(&cocoa_cost(1))).sum();
        let mut sim2 = BspSim::new(HardwareProfile::local48(), 7);
        let t2: f64 = (0..30).map(|_| sim2.iteration_time(&cocoa_cost(2))).sum();
        assert!(t2 > t1 / 2.0, "speedup should be sublinear");
        assert!(t2 < t1, "2 machines should still beat 1");
    }

    #[test]
    fn clock_and_history_accumulate() {
        let mut sim = BspSim::new(HardwareProfile::local48(), 3);
        for _ in 0..10 {
            sim.iteration_time(&cocoa_cost(4));
        }
        assert_eq!(sim.history.len(), 10);
        let sum: f64 = sim.history.iter().sum();
        assert!((sim.elapsed - sum).abs() < 1e-12);
    }

    #[test]
    fn noise_creates_percentile_spread() {
        let mut sim = BspSim::new(HardwareProfile::local48(), 11);
        let ts: Vec<f64> = (0..200).map(|_| sim.iteration_time(&cocoa_cost(16))).collect();
        let p5 = stats::percentile(&ts, 5.0);
        let p95 = stats::percentile(&ts, 95.0);
        assert!(p95 > p5 * 1.02, "expected spread, got p5={p5} p95={p95}");
    }

    #[test]
    fn straggler_tail_grows_with_m() {
        // More machines ⇒ higher chance one straggles ⇒ heavier tail
        // relative to the base compute time.
        let rel_tail = |m: usize| {
            let mut sim = BspSim::new(HardwareProfile::local48(), 13);
            let ts: Vec<f64> = (0..300).map(|_| sim.iteration_time(&cocoa_cost(m))).collect();
            stats::percentile(&ts, 99.0) / stats::median(&ts)
        };
        assert!(rel_tail(64) > 1.0);
    }

    #[test]
    fn ssp_zero_is_bitwise_bsp() {
        let mut bsp = ClusterSim::with_mode(HardwareProfile::local48(), BarrierMode::Bsp, 17);
        let mut ssp0 = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 0 },
            17,
        );
        for _ in 0..40 {
            let a = bsp.iteration_time(&cocoa_cost(16));
            let b = ssp0.iteration_time(&cocoa_cost(16));
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(bsp.elapsed.to_bits(), ssp0.elapsed.to_bits());
        assert_eq!(bsp.read_staleness(), 0);
        assert_eq!(ssp0.read_staleness(), 0);
    }

    #[test]
    fn relaxed_barriers_are_faster_under_noise() {
        // Same seed → same noise realization; the modes only differ in
        // how much waiting they impose.
        let run = |mode: BarrierMode| {
            let mut sim = ClusterSim::with_mode(HardwareProfile::local48(), mode, 23);
            for _ in 0..200 {
                sim.iteration_time(&cocoa_cost(32));
            }
            sim.elapsed
        };
        let bsp = run(BarrierMode::Bsp);
        let ssp = run(BarrierMode::Ssp { staleness: 4 });
        let asn = run(BarrierMode::Async);
        assert!(asn <= ssp && ssp <= bsp, "async={asn} ssp={ssp} bsp={bsp}");
        // With lognormal noise and stragglers over 32 machines the gap
        // is substantial, not an epsilon artifact.
        assert!(asn < bsp * 0.95, "async={asn} bsp={bsp}");
    }

    #[test]
    fn ssp_staleness_stays_within_bound() {
        let mut sim = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 3 },
            29,
        );
        for _ in 0..100 {
            sim.iteration_time(&cocoa_cost(16));
            assert!(sim.read_staleness() <= 3, "staleness {}", sim.read_staleness());
        }
        // Under per-machine noise the clocks do drift apart, so SSP
        // reads are genuinely stale some of the time.
        let mut any_stale = false;
        let mut probe = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 3 },
            31,
        );
        for _ in 0..200 {
            probe.iteration_time(&cocoa_cost(16));
            any_stale |= probe.read_staleness() > 0;
        }
        assert!(any_stale, "SSP never produced a stale read");
    }

    #[test]
    fn rng_streams_separate_equal_length_profile_names() {
        // The pre-fix stream id was `0xC1 + name.len()`, so any two
        // profiles with equal-length names (local48 vs a hypothetical
        // local64) shared one noise realization. The FNV-hash stream
        // must not.
        let a = HardwareProfile::local48();
        let mut b = HardwareProfile::local48();
        b.name = "local64".into();
        assert_eq!(a.name.len(), b.name.len());
        let mut sim_a = ClusterSim::new(a.clone(), 99);
        let mut sim_b = ClusterSim::new(b, 99);
        let da = sim_a.iteration_time(&cocoa_cost(8));
        let db = sim_b.iteration_time(&cocoa_cost(8));
        assert_ne!(da.to_bits(), db.to_bits(), "equal-length names share a stream");
        // Same name ⇒ same stream (the pairing guarantee): a second
        // local48 sim reproduces the draws exactly.
        let mut sim_a2 = ClusterSim::new(a, 99);
        assert_eq!(da.to_bits(), sim_a2.iteration_time(&cocoa_cost(8)).to_bits());
    }

    #[test]
    fn uniform_fleet_is_bitwise_plain_profile() {
        use crate::cluster::FleetSpec;
        for mode in [BarrierMode::Bsp, BarrierMode::Ssp { staleness: 2 }, BarrierMode::Async] {
            let mut plain = ClusterSim::with_mode(HardwareProfile::local48(), mode, 7);
            let mut fleet = ClusterSim::with_fleet(
                FleetSpec::uniform(HardwareProfile::local48()),
                mode,
                7,
            );
            for _ in 0..50 {
                let a = plain.iteration_time(&cocoa_cost(16));
                let b = fleet.iteration_time(&cocoa_cost(16));
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(plain.elapsed.to_bits(), fleet.elapsed.to_bits());
            assert_eq!(plain.spent_dollars.to_bits(), fleet.spent_dollars.to_bits());
        }
    }

    #[test]
    fn slow_fleet_is_never_faster_and_bills_dollars() {
        use crate::cluster::FleetSpec;
        let uniform = FleetSpec::uniform(HardwareProfile::local48());
        let slow = FleetSpec::parse("local48*0.25:slow=3x").unwrap();
        let mut u = ClusterSim::with_fleet(uniform.clone(), BarrierMode::Bsp, 31);
        let mut s = ClusterSim::with_fleet(slow, BarrierMode::Bsp, 31);
        for _ in 0..100 {
            // Same base profile ⇒ same draws; slow nodes only scale
            // them up, so the ordering is pointwise, not statistical.
            let du = u.iteration_time(&cocoa_cost(16));
            let ds = s.iteration_time(&cocoa_cost(16));
            assert!(ds >= du, "slow fleet iterated faster: {ds} < {du}");
        }
        assert!(s.elapsed > u.elapsed);
        // Dollar accounting: wall clock × m × the (uniform) unit rate.
        let rate = HardwareProfile::local48().price_per_machine_second;
        let expect = u.elapsed * 16.0 * rate;
        assert!((u.spent_dollars - expect).abs() < 1e-9 * expect.max(1.0));
        // The slow fleet holds the same machines for longer: it can
        // only cost more.
        assert!(s.spent_dollars > u.spent_dollars);
    }

    #[test]
    fn relaxed_modes_beat_bsp_on_a_heterogeneous_fleet() {
        use crate::cluster::FleetSpec;
        // With a persistently slow group, BSP pays the *max* over that
        // group's noisy draws every iteration; SSP/async pay each slow
        // machine's own average. Same seed ⇒ same draws ⇒ the ordering
        // is exact per seed.
        let run = |mode: BarrierMode| {
            let fleet = FleetSpec::parse("local48*0.25:slow=3x").unwrap();
            let mut sim = ClusterSim::with_fleet(fleet, mode, 23);
            for _ in 0..200 {
                sim.iteration_time(&cocoa_cost(32));
            }
            (sim.elapsed, sim.spent_dollars)
        };
        let (bsp, bsp_cost) = run(BarrierMode::Bsp);
        let (ssp, ssp_cost) = run(BarrierMode::Ssp { staleness: 4 });
        let (asn, asn_cost) = run(BarrierMode::Async);
        assert!(asn <= ssp && ssp <= bsp, "async={asn} ssp={ssp} bsp={bsp}");
        assert!(asn < bsp * 0.99, "no heterogeneity absorption: async={asn} bsp={bsp}");
        // Same machines held for less wall clock ⇒ fewer dollars.
        assert!(asn_cost <= ssp_cost && ssp_cost <= bsp_cost);
    }

    #[test]
    fn reconfiguration_resynchronizes() {
        // The adaptive loop changes m mid-run; that is a global
        // barrier, after which the clock keeps monotonically advancing.
        let mut sim = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 2 },
            5,
        );
        for _ in 0..10 {
            sim.iteration_time(&cocoa_cost(8));
        }
        let before = sim.elapsed;
        sim.iteration_time(&cocoa_cost(32));
        assert!(sim.elapsed > before);
        assert_eq!(sim.read_staleness(), 0, "fresh clocks start in sync");
    }
}

//! The cluster iteration-time simulator — the stand-in for the paper's
//! Spark/YARN testbed, generalized from a pure-BSP barrier to the full
//! [`BarrierMode`] axis.
//!
//! Every machine keeps its **own clock**. One iteration of machine `k`
//! costs
//!
//! ```text
//! d_k = θ_fixed                       (driver bookkeeping)
//!     + sched · m                     (serial task dispatch)
//!     + broadcast(m, model bytes)     (tree, log m rounds)
//!     + compute_k · fleet_factor_k    (lognormal noise + stragglers,
//!                                      scaled by the machine's fleet
//!                                      factor: mixed types, persistent
//!                                      slow nodes — cluster::fleet)
//!     + reduce(m, update bytes)       (tree, log m rounds)
//! ```
//!
//! and the machine starts its next iteration at
//! `max(own clock, barrier)`, where the barrier is the time at which
//! *all* machines finished the iteration `staleness` steps back:
//!
//! * **BSP** — staleness 0: every start waits for everyone's previous
//!   finish, so each iteration costs the slowest machine's `d_k` —
//!   exactly the original `BspSim` pricing.
//! * **SSP(s)** — a machine only blocks when it would run more than
//!   `s` iterations ahead of the slowest; fast machines absorb slow
//!   ones' noise, and a straggler no longer stalls the whole cluster.
//! * **Async** — no barrier: elapsed time is throughput-derived (the
//!   max of independent per-machine clock sums) instead of a per-step
//!   barrier max.
//!
//! All modes consume the RNG identically (m compute draws per
//! iteration, in machine order), so for a fixed seed the three modes
//! price the *same* noise realization — which is what makes the
//! `Async ≤ SSP(s) ≤ BSP` elapsed-time ordering and the
//! `SSP(0) ≡ BSP` equivalence exact, seed by seed, rather than merely
//! statistical (property-tested in `tests/barrier_props.rs`).
//!
//! The Ernest model never sees these mechanisms — it has to
//! *rediscover* the structure from observed times, exactly as it does
//! against real clusters (Tbl E1 checks the fit error).

use std::collections::VecDeque;

use super::barrier::BarrierMode;
use super::fleet::FleetSpec;
use super::network::{broadcast_time, reduce_time};
use super::profile::HardwareProfile;
use crate::optim::driver::IterationTimer;
use crate::optim::IterationCost;
use crate::util::rng::{fnv1a_64, Pcg32};

/// How many committed-iteration barrier times `Async` retains for the
/// staleness probe (its staleness is unbounded in principle; reads
/// report at most this). Tied to the algorithms' snapshot retention so
/// a reported staleness always has a snapshot to serve it.
const ASYNC_STALENESS_WINDOW: usize = crate::optim::stale::MAX_STALE_SNAPSHOTS;

/// A time-varying cluster event, fired when the simulated clock
/// reaches its timestamp. Extends PR 4's *static* slow-node machinery
/// ([`FleetSpec`]) with mid-run dynamics: machines leaving
/// (preemption), returning, and the whole cluster slowing down.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScenarioEvent {
    /// At simulated time `at`, `machines` physical machines are
    /// preempted. Logical slots keep running — survivors host the
    /// orphaned slots round-robin and serialize their compute — so
    /// the *algorithm* is untouched while iterations slow down.
    Preempt { at: f64, machines: usize },
    /// At simulated time `at`, `machines` preempted machines return.
    Restore { at: f64, machines: usize },
    /// At simulated time `at`, every machine's compute scales by
    /// `factor` from now on (a cluster-wide interference episode;
    /// `1.0` ends it).
    SlowDown { at: f64, factor: f64 },
}

impl ScenarioEvent {
    /// The simulated timestamp this event fires at.
    pub fn at(&self) -> f64 {
        match self {
            ScenarioEvent::Preempt { at, .. }
            | ScenarioEvent::Restore { at, .. }
            | ScenarioEvent::SlowDown { at, .. } => *at,
        }
    }
}

impl std::fmt::Display for ScenarioEvent {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScenarioEvent::Preempt { at, machines } => write!(f, "preempt@{at}x{machines}"),
            ScenarioEvent::Restore { at, machines } => write!(f, "restore@{at}x{machines}"),
            ScenarioEvent::SlowDown { at, factor } => write!(f, "slow@{at}x{factor}"),
        }
    }
}

/// A named sequence of [`ScenarioEvent`]s over a physical machine
/// pool. The string form — `pool=16,preempt@5x8,restore@20x8,
/// slow@8x1.5` — is what configs, sweep cell keys and the trace
/// format carry; [`Scenario::parse`] and `Display` round-trip it.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Scenario {
    /// Physical machines backing the cluster. `0` (the default) means
    /// "as many as each request asks for" — preemption then bites any
    /// m; a concrete pool caps how many slots run unshared.
    pub pool: usize,
    /// Events in firing order (sorted on attach).
    pub events: Vec<ScenarioEvent>,
}

impl Scenario {
    /// No events at all — the provably-inert static scenario.
    pub fn is_static(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the comma-separated scenario string. The empty string is
    /// the static scenario.
    pub fn parse(spec: &str) -> crate::Result<Scenario> {
        let mut sc = Scenario::default();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            if let Some(v) = tok.strip_prefix("pool=") {
                sc.pool = v
                    .parse()
                    .map_err(|_| crate::err!("invalid pool '{v}' in scenario '{spec}'"))?;
            } else if let Some(rest) = tok.strip_prefix("preempt@") {
                let (at, arg) = event_parts(rest, spec)?;
                let machines = parse_count(arg, spec)?;
                sc.events.push(ScenarioEvent::Preempt { at, machines });
            } else if let Some(rest) = tok.strip_prefix("restore@") {
                let (at, arg) = event_parts(rest, spec)?;
                let machines = parse_count(arg, spec)?;
                sc.events.push(ScenarioEvent::Restore { at, machines });
            } else if let Some(rest) = tok.strip_prefix("slow@") {
                let (at, arg) = event_parts(rest, spec)?;
                let factor: f64 = arg
                    .parse()
                    .map_err(|_| crate::err!("invalid slow factor '{arg}' in scenario '{spec}'"))?;
                crate::ensure!(
                    factor.is_finite() && factor > 0.0,
                    "slow factor must be positive and finite in scenario '{spec}'"
                );
                sc.events.push(ScenarioEvent::SlowDown { at, factor });
            } else {
                crate::bail!(
                    "unknown scenario token '{tok}' in '{spec}' \
                     (expected pool=N, preempt@TxM, restore@TxM, slow@TxF)"
                );
            }
        }
        Ok(sc)
    }
}

impl std::fmt::Display for Scenario {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut sep = "";
        if self.pool != 0 {
            write!(f, "pool={}", self.pool)?;
            sep = ",";
        }
        for ev in &self.events {
            write!(f, "{sep}{ev}")?;
            sep = ",";
        }
        Ok(())
    }
}

/// Split an event body `"<at>x<arg>"` (the `@` prefix already gone).
fn event_parts<'a>(rest: &'a str, spec: &str) -> crate::Result<(f64, &'a str)> {
    let (t, arg) = rest
        .split_once('x')
        .ok_or_else(|| crate::err!("malformed event '{rest}' in scenario '{spec}' (want T x ARG)"))?;
    let at: f64 = t
        .parse()
        .map_err(|_| crate::err!("invalid event time '{t}' in scenario '{spec}'"))?;
    crate::ensure!(
        at.is_finite() && at >= 0.0,
        "event time must be finite and non-negative in scenario '{spec}'"
    );
    Ok((at, arg))
}

fn parse_count(arg: &str, spec: &str) -> crate::Result<usize> {
    let n: usize = arg
        .parse()
        .map_err(|_| crate::err!("invalid machine count '{arg}' in scenario '{spec}'"))?;
    crate::ensure!(n >= 1, "event machine count must be >= 1 in scenario '{spec}'");
    Ok(n)
}

/// Simulated cluster clock with per-machine progress.
pub struct ClusterSim {
    /// The hardware this cluster is made of — a uniform fleet for the
    /// historical plain-profile constructors.
    pub fleet: FleetSpec,
    pub mode: BarrierMode,
    rng: Pcg32,
    /// Simulated time at which the last machine finished the most
    /// recent iteration (the driver-visible clock).
    pub elapsed: f64,
    /// Dollars billed so far: every allocated machine pays its type's
    /// `$/machine-second` for the full wall clock, computing or waiting
    /// at a barrier.
    pub spent_dollars: f64,
    /// Per-iteration marginal elapsed time (Fig 1(a) percentile bars).
    pub history: Vec<f64>,
    /// Per-machine finish time of that machine's latest iteration.
    clocks: Vec<f64>,
    /// Completion times of recent iterations: `barriers.back()` is the
    /// time all machines finished the latest iteration. Bounded by the
    /// blocking window (staleness + 1; a fixed window for Async).
    barriers: VecDeque<f64>,
    /// Scenario events sorted by timestamp; empty on the static path,
    /// which gates *all* event logic out of `iteration_time`.
    events: Vec<ScenarioEvent>,
    /// Physical pool the events act on (0 = per-request m).
    pool: usize,
    /// Index of the next unfired event.
    next_event: usize,
    /// Machines currently preempted out of the pool.
    preempted: usize,
    /// Cluster-wide compute multiplier set by `SlowDown` events.
    slow_factor: f64,
    /// Fired events with the elapsed time they were applied at (the
    /// `elastic_events.csv` source).
    fired: Vec<(f64, ScenarioEvent)>,
}

impl ClusterSim {
    /// A BSP-mode simulator (the historical default).
    pub fn new(profile: HardwareProfile, seed: u64) -> ClusterSim {
        Self::with_mode(profile, BarrierMode::Bsp, seed)
    }

    /// A simulator over a uniform fleet of one profile in an explicit
    /// barrier mode — bit-identical to `with_fleet` on
    /// [`FleetSpec::uniform`] of the same profile.
    pub fn with_mode(profile: HardwareProfile, mode: BarrierMode, seed: u64) -> ClusterSim {
        Self::with_fleet(FleetSpec::uniform(profile), mode, seed)
    }

    /// A simulator over an arbitrary fleet. The RNG stream is derived
    /// from the FNV-1a hash of the *base profile's name* (not its
    /// length — two profiles with equal-length names must not share a
    /// noise realization), so:
    ///
    /// * every barrier mode prices the same draws (cross-mode pairing,
    ///   as before), and
    /// * every fleet built on the same base profile prices the same
    ///   draws too — uniform-vs-heterogeneous comparisons at one seed
    ///   are paired, not merely distributional.
    pub fn with_fleet(fleet: FleetSpec, mode: BarrierMode, seed: u64) -> ClusterSim {
        ClusterSim {
            rng: Pcg32::new(seed, 0xC1u64 ^ fnv1a_64(fleet.base.name.as_bytes())),
            fleet,
            mode,
            elapsed: 0.0,
            spent_dollars: 0.0,
            history: Vec::new(),
            clocks: Vec::new(),
            barriers: VecDeque::new(),
            events: Vec::new(),
            pool: 0,
            next_event: 0,
            preempted: 0,
            slow_factor: 1.0,
            fired: Vec::new(),
        }
    }

    /// Attach a [`Scenario`]: time-varying preempt/restore/slow-down
    /// events over a physical pool. With an event-free scenario this
    /// is provably inert — `iteration_time`'s event block is gated on
    /// `events.is_empty()`, so the static path's RNG draws and
    /// arithmetic are untouched bit for bit
    /// (`tests/elastic_props.rs`).
    pub fn with_scenario(mut self, scenario: &Scenario) -> ClusterSim {
        self.pool = scenario.pool;
        self.events = scenario.events.clone();
        self.events.sort_by(|a, b| {
            a.at().partial_cmp(&b.at()).unwrap_or(std::cmp::Ordering::Equal)
        });
        self
    }

    /// The base hardware profile (fixed costs, network, noise).
    pub fn profile(&self) -> &HardwareProfile {
        &self.fleet.base
    }

    /// Price one iteration (and advance the simulated clocks). Returns
    /// the marginal increase of the driver-visible elapsed time.
    pub fn iteration_time(&mut self, cost: &IterationCost) -> f64 {
        let m = cost.machines.max(1);
        // Scenario events fire against the clock as it stood *before*
        // this iteration; the whole block is gated so the static path
        // executes exactly the historical code.
        let cap = if self.events.is_empty() {
            m
        } else {
            self.apply_due_events();
            self.capacity(m)
        };
        let p = &self.fleet.base;
        if self.clocks.len() != m {
            // First iteration, or a mid-run reconfiguration (the
            // adaptive loop repartitions): a global barrier — all
            // machines restart in sync at the current elapsed time.
            self.clocks.clear();
            self.clocks.resize(m, self.elapsed);
            self.barriers.clear();
        }

        let base = cost.flops_per_machine / p.flops_per_sec;
        // Everything but compute is identical across machines; the sum
        // order matches the historical BSP formula term for term.
        let fixed = p.iteration_overhead
            + p.sched_per_machine * m as f64
            + broadcast_time(p, m, cost.broadcast_bytes);
        let reduce = reduce_time(p, m, cost.reduce_bytes);

        // The barrier this iteration's starts must respect: the finish
        // of the iteration `staleness` steps back (none while fewer
        // iterations have committed, and never for Async).
        let barrier = match self.mode.staleness_bound() {
            Some(s) if self.barriers.len() > s => {
                Some(self.barriers[self.barriers.len() - 1 - s])
            }
            _ => None,
        };

        let mut done = 0.0f64;
        for k in 0..m {
            let mut compute = if p.noise_sigma > 0.0 {
                base * self.rng.lognormal(0.0, p.noise_sigma)
            } else {
                base
            };
            if p.straggler_prob > 0.0 && self.rng.uniform() < p.straggler_prob {
                compute *= p.straggler_factor;
            }
            // Heterogeneity scales only the compute term, after the
            // draws: RNG consumption is identical across fleets of one
            // base profile, and a uniform fleet's factor of exactly
            // 1.0 leaves the arithmetic untouched bit for bit.
            let factor = self.fleet.compute_factor(k, m);
            if factor != 1.0 {
                compute *= factor;
            }
            // Non-IID data load: machine k only computes over its own
            // share of the rows, so skewed partitions turn the heavy
            // machine into a straggler. Applied after the draws, like
            // the fleet factor — an empty load vector (balanced
            // partitions) leaves the arithmetic untouched bit for bit.
            if !cost.load.is_empty() {
                let lk = cost.load[k.min(cost.load.len() - 1)];
                if lk != 1.0 {
                    compute *= lk;
                }
            }
            // Preemption: the m logical slots share `cap` surviving
            // machines round-robin; a host running `load` slots
            // serializes their compute. Like the fleet factor this
            // scales *after* the draws, so event and static runs at
            // one seed price the same noise realization — the
            // slowdown ordering is pointwise, not statistical.
            if cap < m {
                let host = k % cap;
                let load = (m - host - 1) / cap + 1;
                compute *= load as f64;
            }
            if self.slow_factor != 1.0 {
                compute *= self.slow_factor;
            }
            let d = fixed + compute + reduce;
            let start = match barrier {
                Some(b) => self.clocks[k].max(b),
                None => self.clocks[k],
            };
            let finish = start + d;
            self.clocks[k] = finish;
            done = done.max(finish);
        }

        self.barriers.push_back(done);
        let keep = match self.mode.staleness_bound() {
            Some(s) => s + 1,
            None => ASYNC_STALENESS_WINDOW,
        };
        while self.barriers.len() > keep {
            self.barriers.pop_front();
        }

        let dt = done - self.elapsed;
        self.elapsed = done;
        // Bill the allocation: the machines actually held (`cap`,
        // which is m on the static path) for dt wall-clock seconds,
        // each at its own type's rate. BSP thus pays for the waiting
        // the barrier imposes; the relaxed modes buy more progress for
        // the same machine-seconds; preempted machines stop billing.
        self.spent_dollars += self.fleet.price_rate(cap) * dt;
        self.history.push(dt);
        dt
    }

    /// Fire every event whose timestamp the clock has reached,
    /// recording each in the `fired` log.
    fn apply_due_events(&mut self) {
        while self.next_event < self.events.len() {
            let ev = self.events[self.next_event];
            if ev.at() > self.elapsed {
                break;
            }
            match ev {
                ScenarioEvent::Preempt { machines, .. } => self.preempted += machines,
                ScenarioEvent::Restore { machines, .. } => {
                    self.preempted = self.preempted.saturating_sub(machines);
                }
                ScenarioEvent::SlowDown { factor, .. } => self.slow_factor = factor,
            }
            self.fired.push((self.elapsed, ev));
            self.next_event += 1;
        }
    }

    /// Physical machines available to an m-slot request right now:
    /// `min(m, pool − preempted)`, floored at 1 (the cluster never
    /// vanishes entirely). On the static path this is m.
    pub fn capacity(&self, machines: usize) -> usize {
        if self.events.is_empty() {
            return machines;
        }
        let pool = if self.pool == 0 { machines } else { self.pool };
        pool.saturating_sub(self.preempted).clamp(1, machines)
    }

    /// The attached scenario's events (empty on the static path — the
    /// elastic driver's inertness gate).
    pub fn events(&self) -> &[ScenarioEvent] {
        &self.events
    }

    /// Events fired so far, with the elapsed time each was applied at.
    pub fn fired(&self) -> &[(f64, ScenarioEvent)] {
        &self.fired
    }

    /// Machines currently preempted out of the pool.
    pub fn preempted(&self) -> usize {
        self.preempted
    }

    /// Iteration staleness of the model state the *next* iteration's
    /// fastest reader observes: how many committed iterations are not
    /// yet globally complete at the moment that machine starts. Always
    /// 0 for BSP, at most `s` for SSP(s), reported up to a fixed
    /// window for Async.
    pub fn read_staleness(&self) -> usize {
        if self.clocks.is_empty() {
            return 0;
        }
        let fastest = self.clocks.iter().cloned().fold(f64::INFINITY, f64::min);
        let start = match self.mode.staleness_bound() {
            Some(s) if self.barriers.len() > s => {
                fastest.max(self.barriers[self.barriers.len() - 1 - s])
            }
            _ => fastest,
        };
        // `barriers` is strictly increasing, so the stale ones form a
        // suffix.
        self.barriers.iter().rev().take_while(|&&b| b > start).count()
    }

    /// Serialize the evolving clock state for a [`crate::optim::Checkpoint`]:
    /// per-machine clocks, the barrier window, the RNG position, and
    /// the scenario cursor. Construction inputs (fleet, mode, events)
    /// are *not* included — restore into a sim built with the same
    /// inputs. The `history` and `fired` logs are observability, not
    /// state: they do not affect future pricing and stay empty on a
    /// restored sim.
    pub fn save_state(&self) -> crate::util::json::Json {
        use crate::optim::checkpoint::{f64_to_json, u64_to_json};
        use crate::util::json::Json;
        let (rng_state, rng_inc) = self.rng.raw_state();
        Json::object(vec![
            ("elapsed", f64_to_json(self.elapsed)),
            ("spent_dollars", f64_to_json(self.spent_dollars)),
            ("rng_state", u64_to_json(rng_state)),
            ("rng_inc", u64_to_json(rng_inc)),
            (
                "clocks",
                Json::array(self.clocks.iter().map(|&c| f64_to_json(c))),
            ),
            (
                "barriers",
                Json::array(self.barriers.iter().map(|&b| f64_to_json(b))),
            ),
            ("next_event", Json::num(self.next_event as f64)),
            ("preempted", Json::num(self.preempted as f64)),
            ("slow_factor", f64_to_json(self.slow_factor)),
        ])
    }

    /// Restore the state produced by [`ClusterSim::save_state`]; the
    /// subsequent pricing sequence continues bit-identically.
    pub fn load_state(&mut self, state: &crate::util::json::Json) -> crate::Result<()> {
        use crate::optim::checkpoint::{f64_from_json, u64_from_json};
        use crate::util::json::Json;
        let field = |key: &str| -> crate::Result<&Json> {
            state
                .get(key)
                .ok_or_else(|| crate::err!("missing sim checkpoint field '{key}'"))
        };
        let elapsed = f64_from_json(field("elapsed")?, "elapsed")?;
        let spent = f64_from_json(field("spent_dollars")?, "spent_dollars")?;
        let rng_state = u64_from_json(field("rng_state")?, "rng_state")?;
        let rng_inc = u64_from_json(field("rng_inc")?, "rng_inc")?;
        let mut clocks = Vec::new();
        for (i, c) in field("clocks")?
            .as_array()
            .ok_or_else(|| crate::err!("sim checkpoint field 'clocks' is not an array"))?
            .iter()
            .enumerate()
        {
            clocks.push(f64_from_json(c, &format!("clocks[{i}]"))?);
        }
        let mut barriers = VecDeque::new();
        for (i, b) in field("barriers")?
            .as_array()
            .ok_or_else(|| crate::err!("sim checkpoint field 'barriers' is not an array"))?
            .iter()
            .enumerate()
        {
            barriers.push_back(f64_from_json(b, &format!("barriers[{i}]"))?);
        }
        let next_event = state.req_usize("next_event")?;
        crate::ensure!(
            next_event <= self.events.len(),
            "sim checkpoint fires {} events, scenario has {}",
            next_event,
            self.events.len()
        );
        self.elapsed = elapsed;
        self.spent_dollars = spent;
        self.rng = Pcg32::from_raw(rng_state, rng_inc);
        self.clocks = clocks;
        self.barriers = barriers;
        self.next_event = next_event;
        self.preempted = state.req_usize("preempted")?;
        self.slow_factor = f64_from_json(field("slow_factor")?, "slow_factor")?;
        Ok(())
    }
}

impl IterationTimer for ClusterSim {
    fn price(&mut self, cost: &IterationCost) -> f64 {
        self.iteration_time(cost)
    }

    fn staleness(&self) -> usize {
        self.read_staleness()
    }

    fn mode(&self) -> BarrierMode {
        self.mode
    }
}

/// The historical name for the BSP-mode simulator. Construction via
/// [`ClusterSim::new`] keeps the pure-BSP default; the type is the
/// same so all modes flow through one clock implementation.
pub type BspSim = ClusterSim;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn cocoa_cost(m: usize) -> IterationCost {
        // Default workload: n=8192, d=128, h = n_loc.
        let n_loc = 8192usize.div_ceil(m) as f64;
        IterationCost {
            machines: m,
            flops_per_machine: n_loc * 8.0 * 128.0,
            broadcast_bytes: 4.0 * 128.0,
            reduce_bytes: 4.0 * 128.0,
            load: Vec::new(),
        }
    }

    #[test]
    fn deterministic_profile_is_deterministic() {
        let mut a = BspSim::new(HardwareProfile::ideal(), 1);
        let mut b = BspSim::new(HardwareProfile::ideal(), 2);
        assert_eq!(a.iteration_time(&cocoa_cost(8)), b.iteration_time(&cocoa_cost(8)));
    }

    #[test]
    fn fig1a_shape_u_curve() {
        // The paper's headline system observation: time/iter improves
        // up to ~32 executors, then degrades.
        let mut means = Vec::new();
        for &m in &[1usize, 2, 4, 8, 16, 32, 64, 128] {
            let mut sim = BspSim::new(HardwareProfile::local48(), 42);
            let ts: Vec<f64> = (0..50).map(|_| sim.iteration_time(&cocoa_cost(m))).collect();
            means.push(stats::mean(&ts));
        }
        // Monotone decrease from m=1 to m=8.
        assert!(means[0] > means[1] && means[1] > means[2] && means[2] > means[3]);
        // The minimum is somewhere in 16–64 and not at the extremes.
        // NaN-filtering total-order selection: a NaN mean (e.g. from a
        // degenerate profile edit) must fail the range assert below,
        // not panic inside an unwrap'd partial_cmp — the same latent
        // panic class as the stats::percentile bug fixed in PR 4.
        let min_idx = stats::argmin(&means).expect("at least one finite mean");
        assert!(
            (3..=6).contains(&min_idx),
            "minimum at index {min_idx}: {means:?}"
        );
        // And m=128 is worse than the minimum.
        assert!(means[7] > means[min_idx] * 1.05, "{means:?}");
    }

    #[test]
    fn scaling_is_sublinear() {
        // "doubling the number of cores does not result in halving the
        // time per iteration" — Fig 1(a) discussion.
        let mut sim = BspSim::new(HardwareProfile::local48(), 7);
        let t1: f64 = (0..30).map(|_| sim.iteration_time(&cocoa_cost(1))).sum();
        let mut sim2 = BspSim::new(HardwareProfile::local48(), 7);
        let t2: f64 = (0..30).map(|_| sim2.iteration_time(&cocoa_cost(2))).sum();
        assert!(t2 > t1 / 2.0, "speedup should be sublinear");
        assert!(t2 < t1, "2 machines should still beat 1");
    }

    #[test]
    fn clock_and_history_accumulate() {
        let mut sim = BspSim::new(HardwareProfile::local48(), 3);
        for _ in 0..10 {
            sim.iteration_time(&cocoa_cost(4));
        }
        assert_eq!(sim.history.len(), 10);
        let sum: f64 = sim.history.iter().sum();
        assert!((sim.elapsed - sum).abs() < 1e-12);
    }

    #[test]
    fn noise_creates_percentile_spread() {
        let mut sim = BspSim::new(HardwareProfile::local48(), 11);
        let ts: Vec<f64> = (0..200).map(|_| sim.iteration_time(&cocoa_cost(16))).collect();
        let p5 = stats::percentile(&ts, 5.0);
        let p95 = stats::percentile(&ts, 95.0);
        assert!(p95 > p5 * 1.02, "expected spread, got p5={p5} p95={p95}");
    }

    #[test]
    fn straggler_tail_grows_with_m() {
        // More machines ⇒ higher chance one straggles ⇒ heavier tail
        // relative to the base compute time.
        let rel_tail = |m: usize| {
            let mut sim = BspSim::new(HardwareProfile::local48(), 13);
            let ts: Vec<f64> = (0..300).map(|_| sim.iteration_time(&cocoa_cost(m))).collect();
            stats::percentile(&ts, 99.0) / stats::median(&ts)
        };
        assert!(rel_tail(64) > 1.0);
    }

    #[test]
    fn ssp_zero_is_bitwise_bsp() {
        let mut bsp = ClusterSim::with_mode(HardwareProfile::local48(), BarrierMode::Bsp, 17);
        let mut ssp0 = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 0 },
            17,
        );
        for _ in 0..40 {
            let a = bsp.iteration_time(&cocoa_cost(16));
            let b = ssp0.iteration_time(&cocoa_cost(16));
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(bsp.elapsed.to_bits(), ssp0.elapsed.to_bits());
        assert_eq!(bsp.read_staleness(), 0);
        assert_eq!(ssp0.read_staleness(), 0);
    }

    #[test]
    fn relaxed_barriers_are_faster_under_noise() {
        // Same seed → same noise realization; the modes only differ in
        // how much waiting they impose.
        let run = |mode: BarrierMode| {
            let mut sim = ClusterSim::with_mode(HardwareProfile::local48(), mode, 23);
            for _ in 0..200 {
                sim.iteration_time(&cocoa_cost(32));
            }
            sim.elapsed
        };
        let bsp = run(BarrierMode::Bsp);
        let ssp = run(BarrierMode::Ssp { staleness: 4 });
        let asn = run(BarrierMode::Async);
        assert!(asn <= ssp && ssp <= bsp, "async={asn} ssp={ssp} bsp={bsp}");
        // With lognormal noise and stragglers over 32 machines the gap
        // is substantial, not an epsilon artifact.
        assert!(asn < bsp * 0.95, "async={asn} bsp={bsp}");
    }

    #[test]
    fn ssp_staleness_stays_within_bound() {
        let mut sim = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 3 },
            29,
        );
        for _ in 0..100 {
            sim.iteration_time(&cocoa_cost(16));
            assert!(sim.read_staleness() <= 3, "staleness {}", sim.read_staleness());
        }
        // Under per-machine noise the clocks do drift apart, so SSP
        // reads are genuinely stale some of the time.
        let mut any_stale = false;
        let mut probe = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 3 },
            31,
        );
        for _ in 0..200 {
            probe.iteration_time(&cocoa_cost(16));
            any_stale |= probe.read_staleness() > 0;
        }
        assert!(any_stale, "SSP never produced a stale read");
    }

    #[test]
    fn rng_streams_separate_equal_length_profile_names() {
        // The pre-fix stream id was `0xC1 + name.len()`, so any two
        // profiles with equal-length names (local48 vs a hypothetical
        // local64) shared one noise realization. The FNV-hash stream
        // must not.
        let a = HardwareProfile::local48();
        let mut b = HardwareProfile::local48();
        b.name = "local64".into();
        assert_eq!(a.name.len(), b.name.len());
        let mut sim_a = ClusterSim::new(a.clone(), 99);
        let mut sim_b = ClusterSim::new(b, 99);
        let da = sim_a.iteration_time(&cocoa_cost(8));
        let db = sim_b.iteration_time(&cocoa_cost(8));
        assert_ne!(da.to_bits(), db.to_bits(), "equal-length names share a stream");
        // Same name ⇒ same stream (the pairing guarantee): a second
        // local48 sim reproduces the draws exactly.
        let mut sim_a2 = ClusterSim::new(a, 99);
        assert_eq!(da.to_bits(), sim_a2.iteration_time(&cocoa_cost(8)).to_bits());
    }

    #[test]
    fn uniform_fleet_is_bitwise_plain_profile() {
        use crate::cluster::FleetSpec;
        for mode in [BarrierMode::Bsp, BarrierMode::Ssp { staleness: 2 }, BarrierMode::Async] {
            let mut plain = ClusterSim::with_mode(HardwareProfile::local48(), mode, 7);
            let mut fleet = ClusterSim::with_fleet(
                FleetSpec::uniform(HardwareProfile::local48()),
                mode,
                7,
            );
            for _ in 0..50 {
                let a = plain.iteration_time(&cocoa_cost(16));
                let b = fleet.iteration_time(&cocoa_cost(16));
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(plain.elapsed.to_bits(), fleet.elapsed.to_bits());
            assert_eq!(plain.spent_dollars.to_bits(), fleet.spent_dollars.to_bits());
        }
    }

    #[test]
    fn slow_fleet_is_never_faster_and_bills_dollars() {
        use crate::cluster::FleetSpec;
        let uniform = FleetSpec::uniform(HardwareProfile::local48());
        let slow = FleetSpec::parse("local48*0.25:slow=3x").unwrap();
        let mut u = ClusterSim::with_fleet(uniform.clone(), BarrierMode::Bsp, 31);
        let mut s = ClusterSim::with_fleet(slow, BarrierMode::Bsp, 31);
        for _ in 0..100 {
            // Same base profile ⇒ same draws; slow nodes only scale
            // them up, so the ordering is pointwise, not statistical.
            let du = u.iteration_time(&cocoa_cost(16));
            let ds = s.iteration_time(&cocoa_cost(16));
            assert!(ds >= du, "slow fleet iterated faster: {ds} < {du}");
        }
        assert!(s.elapsed > u.elapsed);
        // Dollar accounting: wall clock × m × the (uniform) unit rate.
        let rate = HardwareProfile::local48().price_per_machine_second;
        let expect = u.elapsed * 16.0 * rate;
        assert!((u.spent_dollars - expect).abs() < 1e-9 * expect.max(1.0));
        // The slow fleet holds the same machines for longer: it can
        // only cost more.
        assert!(s.spent_dollars > u.spent_dollars);
    }

    #[test]
    fn relaxed_modes_beat_bsp_on_a_heterogeneous_fleet() {
        use crate::cluster::FleetSpec;
        // With a persistently slow group, BSP pays the *max* over that
        // group's noisy draws every iteration; SSP/async pay each slow
        // machine's own average. Same seed ⇒ same draws ⇒ the ordering
        // is exact per seed.
        let run = |mode: BarrierMode| {
            let fleet = FleetSpec::parse("local48*0.25:slow=3x").unwrap();
            let mut sim = ClusterSim::with_fleet(fleet, mode, 23);
            for _ in 0..200 {
                sim.iteration_time(&cocoa_cost(32));
            }
            (sim.elapsed, sim.spent_dollars)
        };
        let (bsp, bsp_cost) = run(BarrierMode::Bsp);
        let (ssp, ssp_cost) = run(BarrierMode::Ssp { staleness: 4 });
        let (asn, asn_cost) = run(BarrierMode::Async);
        assert!(asn <= ssp && ssp <= bsp, "async={asn} ssp={ssp} bsp={bsp}");
        assert!(asn < bsp * 0.99, "no heterogeneity absorption: async={asn} bsp={bsp}");
        // Same machines held for less wall clock ⇒ fewer dollars.
        assert!(asn_cost <= ssp_cost && ssp_cost <= bsp_cost);
    }

    #[test]
    fn reconfiguration_resynchronizes() {
        // The adaptive loop changes m mid-run; that is a global
        // barrier, after which the clock keeps monotonically advancing.
        let mut sim = ClusterSim::with_mode(
            HardwareProfile::local48(),
            BarrierMode::Ssp { staleness: 2 },
            5,
        );
        for _ in 0..10 {
            sim.iteration_time(&cocoa_cost(8));
        }
        let before = sim.elapsed;
        sim.iteration_time(&cocoa_cost(32));
        assert!(sim.elapsed > before);
        assert_eq!(sim.read_staleness(), 0, "fresh clocks start in sync");
    }

    #[test]
    fn scenario_parse_display_round_trip() {
        let sc = Scenario::parse("pool=16,preempt@5x8,restore@20x8,slow@8x1.5").unwrap();
        assert_eq!(sc.pool, 16);
        assert_eq!(sc.events.len(), 3);
        assert_eq!(sc.events[0], ScenarioEvent::Preempt { at: 5.0, machines: 8 });
        assert_eq!(sc.events[2], ScenarioEvent::SlowDown { at: 8.0, factor: 1.5 });
        let again = Scenario::parse(&sc.to_string()).unwrap();
        assert_eq!(sc, again);
        assert!(Scenario::parse("").unwrap().is_static());
        for bad in [
            "preempt@5",
            "preempt@x8",
            "preempt@5x0",
            "slow@5x-1",
            "slow@-1x2",
            "pool=abc",
            "vanish@5x8",
        ] {
            assert!(Scenario::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn empty_scenario_is_bitwise_static() {
        for mode in [BarrierMode::Bsp, BarrierMode::Ssp { staleness: 2 }, BarrierMode::Async] {
            let mut plain = ClusterSim::with_mode(HardwareProfile::local48(), mode, 7);
            let mut evented = ClusterSim::with_mode(HardwareProfile::local48(), mode, 7)
                .with_scenario(&Scenario { pool: 16, events: vec![] });
            for _ in 0..50 {
                let a = plain.iteration_time(&cocoa_cost(16));
                let b = evented.iteration_time(&cocoa_cost(16));
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(plain.elapsed.to_bits(), evented.elapsed.to_bits());
            assert_eq!(plain.spent_dollars.to_bits(), evented.spent_dollars.to_bits());
            assert_eq!(evented.capacity(16), 16);
            assert!(evented.fired().is_empty());
        }
    }

    #[test]
    fn preemption_slows_pointwise_and_restore_recovers() {
        // Same seed ⇒ same draws; the load multiplier only scales them
        // up, so the slowdown is pointwise per iteration.
        let sc = Scenario::parse("preempt@0x8,restore@1e6x8").unwrap();
        let mut evented = ClusterSim::new(HardwareProfile::local48(), 23).with_scenario(&sc);
        let mut plain = ClusterSim::new(HardwareProfile::local48(), 23);
        for i in 0..50 {
            let de = evented.iteration_time(&cocoa_cost(16));
            let dp = plain.iteration_time(&cocoa_cost(16));
            assert!(de > dp, "iter {i}: preempted dt {de} !> static {dp}");
        }
        assert_eq!(evented.preempted(), 8);
        assert_eq!(evented.capacity(16), 8);
        assert_eq!(evented.fired().len(), 1);
        // Preempted machines stop billing: fewer machine-seconds per
        // (longer) iteration, so dollars grow slower than 2× wall.
        assert!(evented.spent_dollars < 2.0 * plain.spent_dollars);
        // A restore due immediately brings capacity back.
        let sc2 = Scenario::parse("preempt@0x8,restore@0x8").unwrap();
        let mut back = ClusterSim::new(HardwareProfile::local48(), 23).with_scenario(&sc2);
        back.iteration_time(&cocoa_cost(16));
        assert_eq!(back.preempted(), 0);
        assert_eq!(back.capacity(16), 16);
        assert_eq!(back.fired().len(), 2);
    }

    #[test]
    fn slowdown_scales_compute_pointwise() {
        let sc = Scenario::parse("slow@0x2").unwrap();
        let mut slowed = ClusterSim::new(HardwareProfile::local48(), 29).with_scenario(&sc);
        let mut plain = ClusterSim::new(HardwareProfile::local48(), 29);
        for _ in 0..30 {
            let ds = slowed.iteration_time(&cocoa_cost(8));
            let dp = plain.iteration_time(&cocoa_cost(8));
            assert!(ds > dp, "slowdown did not slow: {ds} !> {dp}");
        }
    }

    #[test]
    fn capacity_never_drops_below_one() {
        let sc = Scenario::parse("pool=4,preempt@0x100").unwrap();
        let mut sim = ClusterSim::new(HardwareProfile::local48(), 3).with_scenario(&sc);
        let dt = sim.iteration_time(&cocoa_cost(8));
        assert!(dt.is_finite() && dt > 0.0);
        assert_eq!(sim.capacity(8), 1);
    }

    #[test]
    fn save_load_state_resumes_bit_identically() {
        let sc = Scenario::parse("pool=16,preempt@0.05x8").unwrap();
        let make = || {
            ClusterSim::with_mode(
                HardwareProfile::local48(),
                BarrierMode::Ssp { staleness: 2 },
                41,
            )
            .with_scenario(&sc)
        };
        let mut full = make();
        for _ in 0..10 {
            full.iteration_time(&cocoa_cost(16));
        }
        let snap = full.save_state();
        let tail: Vec<u64> = (0..10)
            .map(|_| full.iteration_time(&cocoa_cost(16)).to_bits())
            .collect();
        let mut resumed = make();
        resumed
            .load_state(&crate::util::json::Json::parse(&snap.to_string()).unwrap())
            .unwrap();
        let replay: Vec<u64> = (0..10)
            .map(|_| resumed.iteration_time(&cocoa_cost(16)).to_bits())
            .collect();
        assert_eq!(tail, replay, "restored sim diverged");
        assert_eq!(full.elapsed.to_bits(), resumed.elapsed.to_bits());
        assert_eq!(full.spent_dollars.to_bits(), resumed.spent_dollars.to_bits());
    }
}

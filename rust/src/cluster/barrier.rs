//! Barrier modes: how tightly the simulated cluster synchronizes the
//! machines between iterations.
//!
//! The paper's testbed (and the original simulator here) is pure BSP:
//! every iteration ends with a global barrier, so each iteration costs
//! the *slowest* machine's compute time. Petuum-style stale-synchronous
//! parallel (SSP) relaxes that: a machine only blocks when it runs more
//! than `staleness` iterations ahead of the slowest, trading statistical
//! efficiency (updates are computed against stale model state) for
//! throughput. `Async` removes the barrier entirely.
//!
//! `Ssp { staleness: 0 }` is exactly BSP — no machine may run ahead, so
//! everyone proceeds in lockstep — and the simulator prices the two
//! identically (property-tested in `tests/barrier_props.rs`).

/// Coordination regime of one simulated run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BarrierMode {
    /// Bulk-synchronous: global barrier every iteration.
    Bsp,
    /// Stale-synchronous: a machine blocks only when it would run more
    /// than `staleness` iterations ahead of the slowest machine.
    Ssp { staleness: usize },
    /// No barrier at all: machines free-run; the model state a machine
    /// reads can be arbitrarily stale.
    Async,
}

impl BarrierMode {
    /// Canonical wire form: `bsp`, `ssp:<staleness>`, `async`.
    pub fn as_str(&self) -> String {
        match self {
            BarrierMode::Bsp => "bsp".to_string(),
            BarrierMode::Ssp { staleness } => format!("ssp:{staleness}"),
            BarrierMode::Async => "async".to_string(),
        }
    }

    /// Parse the wire form back. Unknown strings are an error with the
    /// accepted grammar spelled out — a config or artifact naming a
    /// mode this build does not know must never be silently served.
    pub fn parse(s: &str) -> crate::Result<BarrierMode> {
        match s.trim() {
            "bsp" => Ok(BarrierMode::Bsp),
            "async" => Ok(BarrierMode::Async),
            other => match other.strip_prefix("ssp:") {
                Some(k) => k
                    .parse::<usize>()
                    .map(|staleness| BarrierMode::Ssp { staleness })
                    .map_err(|_| {
                        crate::err!(
                            "bad SSP staleness '{k}' in barrier mode '{other}' \
                             (expected ssp:<non-negative integer>)"
                        )
                    }),
                None => crate::bail!(
                    "unknown barrier mode '{other}' (expected bsp, ssp:<staleness> or async)"
                ),
            },
        }
    }

    /// The iteration-staleness bound this mode guarantees (None for
    /// `Async`, which guarantees nothing).
    pub fn staleness_bound(&self) -> Option<usize> {
        match self {
            BarrierMode::Bsp => Some(0),
            BarrierMode::Ssp { staleness } => Some(*staleness),
            BarrierMode::Async => None,
        }
    }

    /// The one numeric encoding every CSV column uses:
    /// `bsp` → 0, `ssp:k` → k + 1, `async` → −1. Keeps `ssp:0`
    /// distinguishable from `bsp` across files.
    pub fn csv_id(&self) -> f64 {
        match self {
            BarrierMode::Bsp => 0.0,
            BarrierMode::Ssp { staleness } => 1.0 + *staleness as f64,
            BarrierMode::Async => -1.0,
        }
    }

    /// Inverse of [`Self::csv_id`] (pre-barrier-axis tables carry no
    /// column and default to 0 → BSP).
    pub fn from_csv_id(id: f64) -> BarrierMode {
        if id < 0.0 {
            BarrierMode::Async
        } else if id == 0.0 {
            BarrierMode::Bsp
        } else {
            BarrierMode::Ssp {
                staleness: (id - 1.0) as usize,
            }
        }
    }

    pub fn is_bsp(&self) -> bool {
        matches!(self, BarrierMode::Bsp)
    }
}

impl std::fmt::Display for BarrierMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrip() {
        for mode in [
            BarrierMode::Bsp,
            BarrierMode::Ssp { staleness: 0 },
            BarrierMode::Ssp { staleness: 7 },
            BarrierMode::Async,
        ] {
            assert_eq!(BarrierMode::parse(&mode.as_str()).unwrap(), mode);
        }
        assert_eq!(BarrierMode::parse(" bsp ").unwrap(), BarrierMode::Bsp);
    }

    #[test]
    fn unknown_modes_rejected_with_clear_error() {
        for bad in ["ssp", "ssp:", "ssp:-1", "ssp:two", "bsp2", "sync", ""] {
            let err = BarrierMode::parse(bad).unwrap_err().to_string();
            assert!(
                err.contains("barrier mode") || err.contains("staleness"),
                "unhelpful error for '{bad}': {err}"
            );
        }
    }

    #[test]
    fn staleness_bounds() {
        assert_eq!(BarrierMode::Bsp.staleness_bound(), Some(0));
        assert_eq!(
            BarrierMode::Ssp { staleness: 3 }.staleness_bound(),
            Some(3)
        );
        assert_eq!(BarrierMode::Async.staleness_bound(), None);
    }

    #[test]
    fn csv_id_roundtrips_and_separates_bsp_from_ssp0() {
        for mode in [
            BarrierMode::Bsp,
            BarrierMode::Ssp { staleness: 0 },
            BarrierMode::Ssp { staleness: 7 },
            BarrierMode::Async,
        ] {
            assert_eq!(BarrierMode::from_csv_id(mode.csv_id()), mode);
        }
        assert_ne!(
            BarrierMode::Bsp.csv_id(),
            BarrierMode::Ssp { staleness: 0 }.csv_id()
        );
    }

    #[test]
    fn ordering_is_stable_for_registry_keys() {
        // Bsp < Ssp{..} < Async — model artifacts sort modes with this.
        assert!(BarrierMode::Bsp < BarrierMode::Ssp { staleness: 0 });
        assert!(BarrierMode::Ssp { staleness: 9 } < BarrierMode::Async);
    }
}

//! BSP cluster simulator — the substrate replacing the paper's
//! Spark-on-YARN testbed (see DESIGN.md §2 substitution table).

pub mod bsp;
pub mod network;
pub mod profile;

pub use bsp::BspSim;
pub use network::{broadcast_time, reduce_time, shuffle_time, tree_rounds};
pub use profile::HardwareProfile;

//! Cluster simulator — the substrate replacing the paper's
//! Spark-on-YARN testbed (see DESIGN.md §2 substitution table), now
//! with per-machine clocks, a selectable barrier mode ([`BarrierMode`]:
//! BSP, stale-synchronous, fully async), and heterogeneous fleets
//! ([`FleetSpec`]: mixed machine types, persistent slow nodes,
//! per-machine dollar prices).

pub mod barrier;
pub mod fleet;
pub mod network;
pub mod profile;
pub mod sim;

pub use barrier::BarrierMode;
pub use fleet::FleetSpec;
pub use network::{broadcast_time, reduce_time, shuffle_time, tree_rounds};
pub use profile::HardwareProfile;
pub use sim::{BspSim, ClusterSim, Scenario, ScenarioEvent};

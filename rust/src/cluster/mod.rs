//! Cluster simulator — the substrate replacing the paper's
//! Spark-on-YARN testbed (see DESIGN.md §2 substitution table), now
//! with per-machine clocks and a selectable barrier mode
//! ([`BarrierMode`]: BSP, stale-synchronous, fully async).

pub mod barrier;
pub mod network;
pub mod profile;
pub mod sim;

pub use barrier::BarrierMode;
pub use network::{broadcast_time, reduce_time, shuffle_time, tree_rounds};
pub use profile::HardwareProfile;
pub use sim::{BspSim, ClusterSim};

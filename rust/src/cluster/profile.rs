//! Hardware profiles for the BSP cluster simulator.
//!
//! Each profile stands in for a testbed the paper used (an 8-node
//! 48-core YARN cluster carved into 4-core Spark executors; EC2
//! R3.xlarge instances for the Ernest experiments). Numbers are chosen
//! so the *structure* of iteration time matches the paper's Fig 1(a) —
//! compute ∝ size/m, tree-communication ∝ log m, driver scheduling
//! ∝ m, minimum near 32 executors for the default workload — not to
//! match the authors' absolute seconds (substitution note, DESIGN.md §2).

/// Cost parameters of one simulated cluster.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareProfile {
    pub name: String,
    /// Effective FLOP/s of one executor on this workload (includes the
    /// JVM/Spark inefficiency the paper's testbed had).
    pub flops_per_sec: f64,
    /// Fixed per-iteration driver overhead (task serialization, barrier
    /// bookkeeping) — Ernest's θ0.
    pub iteration_overhead: f64,
    /// Serial driver cost per scheduled executor — Ernest's θ3·m term.
    pub sched_per_machine: f64,
    /// One-way network latency per message.
    pub net_latency: f64,
    /// Network bandwidth in bytes/second (per link).
    pub net_bandwidth: f64,
    /// Lognormal noise sigma on each machine's compute time.
    pub noise_sigma: f64,
    /// Probability a machine straggles in a given iteration.
    pub straggler_prob: f64,
    /// Straggler slowdown factor.
    pub straggler_factor: f64,
    /// Dollar price of one machine-second of this type (what the fleet
    /// pricing layer — `cluster::fleet` — charges while a machine is
    /// allocated, whether it computes, waits at a barrier, or idles).
    pub price_per_machine_second: f64,
}

impl HardwareProfile {
    /// The paper's case-study cluster: 8 nodes × 48 cores carved into
    /// 4-core executors. Tuned so CoCoA on the default workload
    /// (n=8192, d=128) has its time-per-iteration minimum near m≈32 —
    /// the Fig 1(a) shape.
    pub fn local48() -> HardwareProfile {
        HardwareProfile {
            name: "local48".into(),
            flops_per_sec: 2.0e7,
            iteration_overhead: 0.100,
            sched_per_machine: 0.0005,
            net_latency: 0.0008,
            net_bandwidth: 1.25e8, // ~1 Gbps
            noise_sigma: 0.08,
            straggler_prob: 0.02,
            straggler_factor: 2.5,
            // On-prem node amortization: cheaper per machine-second
            // than the cloud instance below.
            price_per_machine_second: 5.0e-5,
        }
    }

    /// EC2 R3.xlarge-like profile (4 vCPU, 30.5 GB) used for the
    /// Ernest system-model experiments (§4).
    pub fn r3_xlarge() -> HardwareProfile {
        HardwareProfile {
            name: "r3_xlarge".into(),
            flops_per_sec: 1.5e7,
            iteration_overhead: 0.150,
            sched_per_machine: 0.0012,
            net_latency: 0.0015,
            net_bandwidth: 6.25e7, // ~500 Mbps
            noise_sigma: 0.12,
            straggler_prob: 0.04,
            straggler_factor: 3.0,
            // ≈ the historical r3.xlarge on-demand rate ($0.333/hr).
            price_per_machine_second: 9.25e-5,
        }
    }

    /// A noise-free profile for deterministic unit tests.
    pub fn ideal() -> HardwareProfile {
        HardwareProfile {
            name: "ideal".into(),
            flops_per_sec: 1.0e8,
            iteration_overhead: 0.05,
            sched_per_machine: 0.001,
            net_latency: 0.001,
            net_bandwidth: 1.0e8,
            noise_sigma: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            // A round unit price keeps dollar arithmetic exact in
            // deterministic tests.
            price_per_machine_second: 1.0e-4,
        }
    }

    /// Look up a profile by name (CLI entry point). `measured:<name>`
    /// resolves through the calibration registry (`crate::calib`,
    /// populated by `--profile-dir` / `hemingway calibrate`); the
    /// built-in names resolve exactly as they always have.
    pub fn by_name(name: &str) -> crate::Result<HardwareProfile> {
        if let Some(measured) = name.strip_prefix(crate::calib::MEASURED_PREFIX) {
            return crate::calib::resolve(measured);
        }
        Ok(match name {
            "local48" => Self::local48(),
            "r3_xlarge" => Self::r3_xlarge(),
            "ideal" => Self::ideal(),
            other => crate::bail!(
                "unknown profile '{other}' (expected local48, r3_xlarge, ideal, \
                 or measured:<name> with --profile-dir)"
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_resolve_by_name() {
        for n in ["local48", "r3_xlarge", "ideal"] {
            assert_eq!(HardwareProfile::by_name(n).unwrap().name, n);
        }
        assert!(HardwareProfile::by_name("quantum").is_err());
    }

    #[test]
    fn measured_prefix_routes_to_the_calibration_registry() {
        // Unloaded measured names fail with guidance, not "unknown".
        let err = HardwareProfile::by_name("measured:profiletest-nope")
            .unwrap_err()
            .to_string();
        assert!(err.contains("not loaded"), "{err}");
        // A registered artifact resolves under the measured: prefix with
        // the bare name (what the simulator's RNG stream is keyed by).
        let art = crate::calib::CalibArtifact {
            name: "profiletest-box".into(),
            host: crate::calib::HostFingerprint::detect(),
            profile: HardwareProfile {
                name: "profiletest-box".into(),
                ..HardwareProfile::r3_xlarge()
            },
            compute_rmse: 0.0,
            sched_rmse: 0.0,
            net_rmse: 0.0,
            compute_samples: 3,
            sched_samples: 3,
            net_samples: 3,
            wall_seconds: 0.1,
        };
        crate::calib::register(&art);
        let p = HardwareProfile::by_name("measured:profiletest-box").unwrap();
        assert_eq!(p.name, "profiletest-box");
        assert_eq!(p.flops_per_sec, HardwareProfile::r3_xlarge().flops_per_sec);
    }

    #[test]
    fn ideal_profile_is_noise_free() {
        let p = HardwareProfile::ideal();
        assert_eq!(p.noise_sigma, 0.0);
        assert_eq!(p.straggler_prob, 0.0);
    }

    #[test]
    fn every_profile_has_a_positive_price() {
        for n in ["local48", "r3_xlarge", "ideal"] {
            let p = HardwareProfile::by_name(n).unwrap();
            assert!(
                p.price_per_machine_second > 0.0 && p.price_per_machine_second.is_finite(),
                "{n} price {}",
                p.price_per_machine_second
            );
        }
    }
}

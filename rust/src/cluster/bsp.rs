//! The BSP iteration-time simulator — the stand-in for the paper's
//! Spark/YARN testbed.
//!
//! One iteration of a data-parallel BSP algorithm is priced as
//!
//! ```text
//! t = θ_fixed                       (driver bookkeeping)
//!   + sched · m                     (serial task dispatch)
//!   + broadcast(m, model bytes)     (tree, log m rounds)
//!   + max_k compute_k               (barrier: slowest machine)
//!   + reduce(m, update bytes)       (tree, log m rounds)
//! ```
//!
//! with per-machine lognormal noise and occasional stragglers on the
//! compute term. The Ernest model never sees these mechanisms — it has
//! to *rediscover* the structure from observed times, exactly as it
//! does against real clusters (Tbl E1 checks the fit error).

use super::network::{broadcast_time, reduce_time};
use super::profile::HardwareProfile;
use crate::optim::driver::IterationTimer;
use crate::optim::IterationCost;
use crate::util::rng::Pcg32;

/// Simulated BSP cluster clock.
pub struct BspSim {
    pub profile: HardwareProfile,
    rng: Pcg32,
    /// Accumulated simulated time (seconds).
    pub elapsed: f64,
    /// Per-iteration history (for Fig 1(a) percentile bars).
    pub history: Vec<f64>,
}

impl BspSim {
    pub fn new(profile: HardwareProfile, seed: u64) -> BspSim {
        BspSim {
            rng: Pcg32::new(seed, 0xC1u64 + profile.name.len() as u64),
            profile,
            elapsed: 0.0,
            history: Vec::new(),
        }
    }

    /// Price one iteration (and advance the simulated clock).
    pub fn iteration_time(&mut self, cost: &IterationCost) -> f64 {
        let p = &self.profile;
        let m = cost.machines;

        // Barrier: slowest machine's compute.
        let base = cost.flops_per_machine / p.flops_per_sec;
        let mut slowest = 0.0f64;
        for _ in 0..m {
            let mut t = if p.noise_sigma > 0.0 {
                base * self.rng.lognormal(0.0, p.noise_sigma)
            } else {
                base
            };
            if p.straggler_prob > 0.0 && self.rng.uniform() < p.straggler_prob {
                t *= p.straggler_factor;
            }
            slowest = slowest.max(t);
        }

        let t = p.iteration_overhead
            + p.sched_per_machine * m as f64
            + broadcast_time(p, m, cost.broadcast_bytes)
            + slowest
            + reduce_time(p, m, cost.reduce_bytes);
        self.elapsed += t;
        self.history.push(t);
        t
    }
}

impl IterationTimer for BspSim {
    fn price(&mut self, cost: &IterationCost) -> f64 {
        self.iteration_time(cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn cocoa_cost(m: usize) -> IterationCost {
        // Default workload: n=8192, d=128, h = n_loc.
        let n_loc = 8192usize.div_ceil(m) as f64;
        IterationCost {
            machines: m,
            flops_per_machine: n_loc * 8.0 * 128.0,
            broadcast_bytes: 4.0 * 128.0,
            reduce_bytes: 4.0 * 128.0,
        }
    }

    #[test]
    fn deterministic_profile_is_deterministic() {
        let mut a = BspSim::new(HardwareProfile::ideal(), 1);
        let mut b = BspSim::new(HardwareProfile::ideal(), 2);
        assert_eq!(a.iteration_time(&cocoa_cost(8)), b.iteration_time(&cocoa_cost(8)));
    }

    #[test]
    fn fig1a_shape_u_curve() {
        // The paper's headline system observation: time/iter improves
        // up to ~32 executors, then degrades.
        let mut means = Vec::new();
        for &m in &[1usize, 2, 4, 8, 16, 32, 64, 128] {
            let mut sim = BspSim::new(HardwareProfile::local48(), 42);
            let ts: Vec<f64> = (0..50).map(|_| sim.iteration_time(&cocoa_cost(m))).collect();
            means.push(stats::mean(&ts));
        }
        // Monotone decrease from m=1 to m=8.
        assert!(means[0] > means[1] && means[1] > means[2] && means[2] > means[3]);
        // The minimum is somewhere in 16–64 and not at the extremes.
        let min_idx = means
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert!(
            (3..=6).contains(&min_idx),
            "minimum at index {min_idx}: {means:?}"
        );
        // And m=128 is worse than the minimum.
        assert!(means[7] > means[min_idx] * 1.05, "{means:?}");
    }

    #[test]
    fn scaling_is_sublinear() {
        // "doubling the number of cores does not result in halving the
        // time per iteration" — Fig 1(a) discussion.
        let mut sim = BspSim::new(HardwareProfile::local48(), 7);
        let t1: f64 = (0..30).map(|_| sim.iteration_time(&cocoa_cost(1))).sum();
        let mut sim2 = BspSim::new(HardwareProfile::local48(), 7);
        let t2: f64 = (0..30).map(|_| sim2.iteration_time(&cocoa_cost(2))).sum();
        assert!(t2 > t1 / 2.0, "speedup should be sublinear");
        assert!(t2 < t1, "2 machines should still beat 1");
    }

    #[test]
    fn clock_and_history_accumulate() {
        let mut sim = BspSim::new(HardwareProfile::local48(), 3);
        for _ in 0..10 {
            sim.iteration_time(&cocoa_cost(4));
        }
        assert_eq!(sim.history.len(), 10);
        let sum: f64 = sim.history.iter().sum();
        assert!((sim.elapsed - sum).abs() < 1e-12);
    }

    #[test]
    fn noise_creates_percentile_spread() {
        let mut sim = BspSim::new(HardwareProfile::local48(), 11);
        let ts: Vec<f64> = (0..200).map(|_| sim.iteration_time(&cocoa_cost(16))).collect();
        let p5 = stats::percentile(&ts, 5.0);
        let p95 = stats::percentile(&ts, 95.0);
        assert!(p95 > p5 * 1.02, "expected spread, got p5={p5} p95={p95}");
    }

    #[test]
    fn straggler_tail_grows_with_m() {
        // More machines ⇒ higher chance one straggles ⇒ heavier tail
        // relative to the base compute time.
        let rel_tail = |m: usize| {
            let mut sim = BspSim::new(HardwareProfile::local48(), 13);
            let ts: Vec<f64> = (0..300).map(|_| sim.iteration_time(&cocoa_cost(m))).collect();
            stats::percentile(&ts, 99.0) / stats::median(&ts)
        };
        assert!(rel_tail(64) > 1.0);
    }
}

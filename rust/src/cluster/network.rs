//! Collective-communication cost models.
//!
//! The BSP iteration of every algorithm here is: broadcast the model
//! (driver → machines), compute locally, tree-reduce the updates
//! (machines → driver). Costs follow the standard LogP-style models
//! Ernest's feature set was derived from: a tree collective over m
//! machines takes ⌈log₂(m+1)⌉ rounds, each paying latency + payload.

use super::profile::HardwareProfile;

/// Rounds in a binomial tree over `m` participants plus the driver.
pub fn tree_rounds(m: usize) -> usize {
    // m = 1 is a single link (one round).
    (usize::BITS - m.leading_zeros()) as usize
}

/// Broadcast `bytes` from the driver to `m` machines.
pub fn broadcast_time(p: &HardwareProfile, m: usize, bytes: f64) -> f64 {
    if m == 0 || bytes <= 0.0 {
        return 0.0;
    }
    tree_rounds(m) as f64 * (p.net_latency + bytes / p.net_bandwidth)
}

/// Tree-reduce `bytes`-sized contributions from `m` machines.
/// Payload stays constant up the tree (elementwise reduction).
pub fn reduce_time(p: &HardwareProfile, m: usize, bytes: f64) -> f64 {
    if m == 0 || bytes <= 0.0 {
        return 0.0;
    }
    tree_rounds(m) as f64 * (p.net_latency + bytes / p.net_bandwidth)
}

/// All-to-all shuffle of `bytes` per machine (used by repartitioning
/// in the adaptive loop; not on the per-iteration path). Free when
/// there is nothing to exchange (`m <= 1` or no payload).
pub fn shuffle_time(p: &HardwareProfile, m: usize, bytes_per_machine: f64) -> f64 {
    if m <= 1 || bytes_per_machine <= 0.0 {
        return 0.0;
    }
    // Each machine exchanges (m-1)/m of its data with peers; bisection
    // bandwidth limits to roughly m parallel transfers.
    let cross = bytes_per_machine * (m - 1) as f64 / m as f64;
    p.net_latency * (m - 1) as f64 / m as f64 + cross / p.net_bandwidth + p.net_latency
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_rounds_log2() {
        assert_eq!(tree_rounds(1), 1);
        assert_eq!(tree_rounds(2), 2);
        assert_eq!(tree_rounds(3), 2);
        assert_eq!(tree_rounds(4), 3);
        assert_eq!(tree_rounds(128), 8);
    }

    #[test]
    fn broadcast_grows_logarithmically() {
        let p = HardwareProfile::ideal();
        let b = |m| broadcast_time(&p, m, 4096.0);
        assert!(b(2) < b(16));
        assert!(b(16) < b(128));
        // log growth: doubling machines adds at most one round.
        assert!((b(128) - b(64)) <= (p.net_latency + 4096.0 / p.net_bandwidth) + 1e-12);
    }

    #[test]
    fn zero_cases() {
        let p = HardwareProfile::ideal();
        assert_eq!(broadcast_time(&p, 0, 100.0), 0.0);
        assert_eq!(reduce_time(&p, 4, 0.0), 0.0);
        assert_eq!(shuffle_time(&p, 1, 1e6), 0.0);
    }

    #[test]
    fn shuffle_scales_with_payload() {
        let p = HardwareProfile::ideal();
        assert!(shuffle_time(&p, 8, 1e6) < shuffle_time(&p, 8, 1e7));
    }

    // ---- property tests (util::quickcheck) --------------------------

    use crate::util::quickcheck::{forall, Gen};

    /// A random but physically sane profile for the properties.
    fn random_profile(g: &mut Gen) -> HardwareProfile {
        HardwareProfile {
            name: "prop".into(),
            flops_per_sec: g.f64_in(1e6, 1e9),
            iteration_overhead: g.f64_in(1e-3, 0.5),
            sched_per_machine: g.f64_in(0.0, 1e-2),
            net_latency: g.f64_in(1e-5, 1e-2),
            net_bandwidth: g.f64_in(1e6, 1e9),
            noise_sigma: g.f64_in(0.0, 0.3),
            straggler_prob: g.f64_in(0.0, 0.1),
            straggler_factor: g.f64_in(1.0, 5.0),
            price_per_machine_second: g.f64_in(1e-6, 1e-3),
        }
    }

    #[test]
    fn prop_tree_rounds_closed_form_and_monotone() {
        // tree_rounds(m) = ⌈log₂(m+1)⌉, and it never decreases in m.
        forall(
            "tree_rounds = ceil(log2(m+1)) and monotone",
            500,
            |g| (g.usize_in(0, 1 << 20), ()),
            |&m, _| {
                let ceil_log2 = (m + 1).next_power_of_two().trailing_zeros() as usize;
                tree_rounds(m) == ceil_log2
                    && (m == 0 || tree_rounds(m - 1) <= tree_rounds(m))
            },
        );
    }

    #[test]
    fn prop_collectives_monotone_in_bytes() {
        forall(
            "broadcast/reduce/shuffle are monotone in bytes",
            300,
            |g| {
                let p = random_profile(g);
                let m = g.usize_in(1, 512);
                let lo = g.f64_in(0.0, 1e7);
                let hi = lo + g.f64_in(0.0, 1e7);
                ((m, lo, hi), p)
            },
            |&(m, lo, hi), p| {
                broadcast_time(p, m, lo) <= broadcast_time(p, m, hi)
                    && reduce_time(p, m, lo) <= reduce_time(p, m, hi)
                    && shuffle_time(p, m, lo) <= shuffle_time(p, m, hi)
            },
        );
    }

    #[test]
    fn prop_collectives_zero_on_edge_cases() {
        // m == 0 and bytes <= 0 are free for every collective; every
        // other configuration costs strictly more than nothing.
        forall(
            "collectives are zero exactly on the documented edges",
            300,
            |g| {
                let p = random_profile(g);
                let m = g.usize_in(0, 256);
                let bytes = if g.bool() {
                    g.f64_in(-1e6, 0.0)
                } else {
                    g.f64_in(1.0, 1e8)
                };
                ((m, bytes), p)
            },
            |&(m, bytes), p| {
                let zero_edge = m == 0 || bytes <= 0.0;
                let bc = broadcast_time(p, m, bytes);
                let rd = reduce_time(p, m, bytes);
                let sh = shuffle_time(p, m, bytes);
                if zero_edge {
                    bc == 0.0 && rd == 0.0 && sh == 0.0
                } else {
                    bc > 0.0 && rd > 0.0 && (m == 1) == (sh == 0.0)
                }
            },
        );
    }
}

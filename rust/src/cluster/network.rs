//! Collective-communication cost models.
//!
//! The BSP iteration of every algorithm here is: broadcast the model
//! (driver → machines), compute locally, tree-reduce the updates
//! (machines → driver). Costs follow the standard LogP-style models
//! Ernest's feature set was derived from: a tree collective over m
//! machines takes ⌈log₂(m+1)⌉ rounds, each paying latency + payload.

use super::profile::HardwareProfile;

/// Rounds in a binomial tree over `m` participants plus the driver.
pub fn tree_rounds(m: usize) -> usize {
    // m = 1 is a single link (one round).
    (usize::BITS - m.leading_zeros()) as usize
}

/// Broadcast `bytes` from the driver to `m` machines.
pub fn broadcast_time(p: &HardwareProfile, m: usize, bytes: f64) -> f64 {
    if m == 0 || bytes <= 0.0 {
        return 0.0;
    }
    tree_rounds(m) as f64 * (p.net_latency + bytes / p.net_bandwidth)
}

/// Tree-reduce `bytes`-sized contributions from `m` machines.
/// Payload stays constant up the tree (elementwise reduction).
pub fn reduce_time(p: &HardwareProfile, m: usize, bytes: f64) -> f64 {
    if m == 0 || bytes <= 0.0 {
        return 0.0;
    }
    tree_rounds(m) as f64 * (p.net_latency + bytes / p.net_bandwidth)
}

/// All-to-all shuffle of `bytes` per machine (used by repartitioning
/// in the adaptive loop; not on the per-iteration path).
pub fn shuffle_time(p: &HardwareProfile, m: usize, bytes_per_machine: f64) -> f64 {
    if m <= 1 {
        return 0.0;
    }
    // Each machine exchanges (m-1)/m of its data with peers; bisection
    // bandwidth limits to roughly m parallel transfers.
    let cross = bytes_per_machine * (m - 1) as f64 / m as f64;
    p.net_latency * (m - 1) as f64 / m as f64 + cross / p.net_bandwidth + p.net_latency
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tree_rounds_log2() {
        assert_eq!(tree_rounds(1), 1);
        assert_eq!(tree_rounds(2), 2);
        assert_eq!(tree_rounds(3), 2);
        assert_eq!(tree_rounds(4), 3);
        assert_eq!(tree_rounds(128), 8);
    }

    #[test]
    fn broadcast_grows_logarithmically() {
        let p = HardwareProfile::ideal();
        let b = |m| broadcast_time(&p, m, 4096.0);
        assert!(b(2) < b(16));
        assert!(b(16) < b(128));
        // log growth: doubling machines adds at most one round.
        assert!((b(128) - b(64)) <= (p.net_latency + 4096.0 / p.net_bandwidth) + 1e-12);
    }

    #[test]
    fn zero_cases() {
        let p = HardwareProfile::ideal();
        assert_eq!(broadcast_time(&p, 0, 100.0), 0.0);
        assert_eq!(reduce_time(&p, 4, 0.0), 0.0);
        assert_eq!(shuffle_time(&p, 1, 1e6), 0.0);
    }

    #[test]
    fn shuffle_scales_with_payload() {
        let p = HardwareProfile::ideal();
        assert!(shuffle_time(&p, 8, 1e6) < shuffle_time(&p, 8, 1e7));
    }
}

//! Heterogeneous fleets: what a cluster is actually *made of*.
//!
//! The paper's testbed — and every simulator in this repo until now —
//! treats a cluster as m identical clones of one [`HardwareProfile`].
//! Real deployments mix instance generations, carry persistently slow
//! nodes, and price machine types differently (Dünner et al. show
//! distributed-ML iteration time on Spark is dominated by exactly this
//! machine-level heterogeneity; Tsianos et al. frame the machine count
//! itself as a cost trade-off). A [`FleetSpec`] describes such a
//! cluster:
//!
//! * a **base profile** — fixed per-iteration costs, the network, the
//!   noise model, and the compute rate of the even-ranked machines;
//! * an optional **secondary profile** (`mixed:` fleets) — odd-ranked
//!   machines compute at the secondary type's rate;
//! * a **persistent-slow-node fraction** — the first
//!   `round(fraction·m)` machines compute `slow_factor`× slower, every
//!   iteration, unlike the profile's transient stragglers;
//! * **per-machine prices** — every machine bills its own type's
//!   `$/machine-second` for the full wall-clock of the run (waiting at
//!   a barrier is not free).
//!
//! ## Wire grammar (strict)
//!
//! ```text
//! fleet      := preset | mixed | shaped
//! mixed      := "mixed:" profile "+" profile       # even ranks get the
//!                                                  # first type, odd the second
//! shaped     := profile [ "*" fraction ] [ ":slow=" factor "x" ]
//! profile    := a HardwareProfile name (local48, r3_xlarge, ideal)
//! preset     := "mixed48"     = mixed:local48+r3_xlarge
//!             | "straggly48"  = local48*0.25:slow=3x
//! ```
//!
//! A bare profile name parses to the **uniform fleet** of that profile,
//! which the simulator prices bit-identically to the plain-profile path
//! (property-tested in `tests/barrier_props.rs`) — fleets are a strict
//! generalization, never a behavior change for homogeneous clusters.
//!
//! Heterogeneity only multiplies each machine's *compute* term; the
//! fixed driver costs, the collectives and the noise draws stay on the
//! base profile, so RNG consumption is identical across fleets of the
//! same base and cross-fleet comparisons at one seed are paired the
//! same way cross-barrier-mode comparisons are.

use super::profile::HardwareProfile;

/// Default slowdown when a spec names a slow fraction without a factor
/// (`"local48*0.3"`).
pub const DEFAULT_SLOW_FACTOR: f64 = 2.0;

/// Named fleet presets: shorthand → canonical spec. `parse` accepts
/// either form; the preset name is kept as the fleet's wire name so it
/// round-trips.
pub const PRESETS: &[(&str, &str)] = &[
    ("mixed48", "mixed:local48+r3_xlarge"),
    ("straggly48", "local48*0.25:slow=3x"),
];

/// A heterogeneous (or trivially uniform) cluster description.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSpec {
    /// Canonical wire name — the string `parse` accepts and the id
    /// that appears in sweep cell keys and model artifacts.
    pub name: String,
    /// Primary machine type: fixed costs, network, noise, and the
    /// compute rate of even-ranked machines.
    pub base: HardwareProfile,
    /// Secondary machine type (`mixed:` fleets); odd-ranked machines
    /// compute at this type's rate and bill at its price.
    pub secondary: Option<HardwareProfile>,
    /// Fraction of machines that are persistently slow (in `[0, 1]`).
    pub slow_fraction: f64,
    /// Compute slowdown of a persistent slow node (≥ 1).
    pub slow_factor: f64,
}

impl FleetSpec {
    /// The uniform fleet of one profile — the degenerate case every
    /// pre-fleet code path maps onto. Its wire name is the profile
    /// name itself.
    pub fn uniform(base: HardwareProfile) -> FleetSpec {
        FleetSpec {
            name: base.name.clone(),
            base,
            secondary: None,
            slow_fraction: 0.0,
            slow_factor: 1.0,
        }
    }

    /// Whether every machine is identical (no secondary type, no
    /// persistent slow nodes).
    pub fn is_uniform(&self) -> bool {
        self.secondary.is_none() && self.slow_fraction == 0.0
    }

    /// Parse the strict wire grammar (see module docs), including the
    /// named presets. Anything unrecognized is an error with the
    /// grammar spelled out — a config naming a fleet this build does
    /// not know must never silently run a uniform cluster instead.
    pub fn parse(s: &str) -> crate::Result<FleetSpec> {
        let input = s.trim();
        crate::ensure!(!input.is_empty(), "empty fleet spec");
        if let Some((_, canonical)) = PRESETS.iter().find(|(name, _)| *name == input) {
            let mut fleet = Self::parse(canonical)?;
            fleet.name = input.to_string();
            return Ok(fleet);
        }
        if let Some(rest) = input.strip_prefix("mixed:") {
            let mut parts = rest.split('+');
            let (a, b) = match (parts.next(), parts.next(), parts.next()) {
                (Some(a), Some(b), None) => (a.trim(), b.trim()),
                _ => crate::bail!(
                    "bad mixed fleet '{input}' (expected mixed:<profile>+<profile>)"
                ),
            };
            let base = HardwareProfile::by_name(a)?;
            let secondary = HardwareProfile::by_name(b)?;
            return Ok(FleetSpec {
                name: input.to_string(),
                base,
                secondary: Some(secondary),
                slow_fraction: 0.0,
                slow_factor: 1.0,
            });
        }
        // shaped := profile [ "*" fraction ] [ ":slow=" factor "x" ]
        let (head, slow_factor) = match input.split_once(":slow=") {
            Some((head, tail)) => {
                let digits = tail.strip_suffix('x').ok_or_else(|| {
                    crate::err!(
                        "bad slow factor '{tail}' in fleet '{input}' (expected :slow=<factor>x)"
                    )
                })?;
                let f: f64 = digits.parse().map_err(|_| {
                    crate::err!(
                        "bad slow factor '{digits}' in fleet '{input}' (expected a number ≥ 1)"
                    )
                })?;
                crate::ensure!(
                    f.is_finite() && f >= 1.0,
                    "slow factor must be finite and ≥ 1, got {f} in fleet '{input}'"
                );
                (head, Some(f))
            }
            None => (input, None),
        };
        let (profile_name, slow_fraction) = match head.split_once('*') {
            Some((p, frac)) => {
                let f: f64 = frac.parse().map_err(|_| {
                    crate::err!(
                        "bad slow fraction '{frac}' in fleet '{input}' \
                         (expected <profile>*<fraction in [0,1]>)"
                    )
                })?;
                crate::ensure!(
                    f.is_finite() && (0.0..=1.0).contains(&f),
                    "slow fraction must be in [0, 1], got {f} in fleet '{input}'"
                );
                (p.trim(), f)
            }
            None => (head.trim(), 0.0),
        };
        if slow_factor.is_some() && slow_fraction == 0.0 {
            crate::bail!(
                "fleet '{input}' names a slow factor but no slow machines \
                 (write <profile>*<fraction>:slow=<factor>x)"
            );
        }
        let base = HardwareProfile::by_name(profile_name)?;
        Ok(FleetSpec {
            name: input.to_string(),
            base,
            secondary: None,
            slow_fraction,
            slow_factor: slow_factor.unwrap_or(if slow_fraction > 0.0 {
                DEFAULT_SLOW_FACTOR
            } else {
                1.0
            }),
        })
    }

    /// How many of an m-machine allocation are persistently slow.
    pub fn slow_count(&self, m: usize) -> usize {
        ((self.slow_fraction * m as f64).round() as usize).min(m)
    }

    /// The machine type serving rank `k` (even ranks: base; odd ranks:
    /// the secondary type on mixed fleets). Rank-parity rather than a
    /// prefix split keeps the mix stable when the adaptive loop
    /// changes m mid-run.
    pub fn machine_profile(&self, k: usize) -> &HardwareProfile {
        match &self.secondary {
            Some(sec) if k % 2 == 1 => sec,
            _ => &self.base,
        }
    }

    /// Multiplier on machine k's *compute* time relative to the base
    /// profile. Exactly 1.0 on a uniform fleet — the bit-identity
    /// guarantee the simulator's uniform-≡-plain property rests on.
    pub fn compute_factor(&self, k: usize, m: usize) -> f64 {
        let mut factor = 1.0;
        if let Some(sec) = &self.secondary {
            if k % 2 == 1 {
                factor = self.base.flops_per_sec / sec.flops_per_sec;
            }
        }
        if k < self.slow_count(m) {
            factor *= self.slow_factor;
        }
        factor
    }

    /// Dollars per wall-clock second of an m-machine allocation —
    /// every machine bills its own type's rate for the whole run,
    /// computing or waiting.
    pub fn price_rate(&self, m: usize) -> f64 {
        (0..m)
            .map(|k| self.machine_profile(k).price_per_machine_second)
            .sum()
    }

    /// Dollar cost of `elapsed` simulated seconds at m machines.
    pub fn dollars(&self, elapsed: f64, m: usize) -> f64 {
        elapsed * self.price_rate(m)
    }
}

impl std::fmt::Display for FleetSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_profile_parses_to_uniform() {
        for name in ["local48", "r3_xlarge", "ideal"] {
            let fleet = FleetSpec::parse(name).unwrap();
            assert_eq!(fleet, FleetSpec::uniform(HardwareProfile::by_name(name).unwrap()));
            assert!(fleet.is_uniform());
            assert_eq!(fleet.name, name);
            // Uniform ⇒ every machine computes at factor exactly 1.
            for k in 0..8 {
                assert_eq!(fleet.compute_factor(k, 8), 1.0);
            }
        }
    }

    #[test]
    fn shaped_fleet_parses_fraction_and_factor() {
        let fleet = FleetSpec::parse("local48*0.3:slow=2x").unwrap();
        assert_eq!(fleet.base.name, "local48");
        assert_eq!(fleet.slow_fraction, 0.3);
        assert_eq!(fleet.slow_factor, 2.0);
        assert!(!fleet.is_uniform());
        // round(0.3·10) = 3 slow machines; they (and only they) pay 2×.
        assert_eq!(fleet.slow_count(10), 3);
        assert_eq!(fleet.compute_factor(0, 10), 2.0);
        assert_eq!(fleet.compute_factor(2, 10), 2.0);
        assert_eq!(fleet.compute_factor(3, 10), 1.0);
        assert_eq!(fleet.compute_factor(9, 10), 1.0);
        // Fraction without factor defaults to 2×.
        let dft = FleetSpec::parse("local48*0.5").unwrap();
        assert_eq!(dft.slow_factor, DEFAULT_SLOW_FACTOR);
        assert_eq!(dft.slow_count(4), 2);
    }

    #[test]
    fn mixed_fleet_alternates_types() {
        let fleet = FleetSpec::parse("mixed:r3_xlarge+local48").unwrap();
        assert_eq!(fleet.base.name, "r3_xlarge");
        assert_eq!(fleet.secondary.as_ref().unwrap().name, "local48");
        assert!(!fleet.is_uniform());
        // Odd ranks run on the (here faster) secondary type: their
        // compute factor is flops_base / flops_secondary < 1.
        let expect = 1.5e7 / 2.0e7;
        assert_eq!(fleet.compute_factor(0, 4), 1.0);
        assert_eq!(fleet.compute_factor(1, 4), expect);
        assert_eq!(fleet.compute_factor(2, 4), 1.0);
        assert_eq!(fleet.compute_factor(3, 4), expect);
        // Each machine bills its own type.
        let r3 = HardwareProfile::r3_xlarge().price_per_machine_second;
        let l48 = HardwareProfile::local48().price_per_machine_second;
        assert!((fleet.price_rate(4) - (2.0 * r3 + 2.0 * l48)).abs() < 1e-15);
        assert!((fleet.dollars(10.0, 2) - 10.0 * (r3 + l48)).abs() < 1e-12);
    }

    #[test]
    fn presets_resolve_and_keep_their_name() {
        let fleet = FleetSpec::parse("straggly48").unwrap();
        assert_eq!(fleet.name, "straggly48");
        assert_eq!(fleet.base.name, "local48");
        assert_eq!(fleet.slow_fraction, 0.25);
        assert_eq!(fleet.slow_factor, 3.0);
        let mixed = FleetSpec::parse("mixed48").unwrap();
        assert_eq!(mixed.name, "mixed48");
        assert_eq!(mixed.base.name, "local48");
        assert_eq!(mixed.secondary.as_ref().unwrap().name, "r3_xlarge");
    }

    #[test]
    fn strict_parse_rejects_malformed_specs() {
        for bad in [
            "",
            "quantum",                    // unknown profile
            "mixed:local48",              // missing second type
            "mixed:local48+r3_xlarge+x",  // too many types
            "mixed:local48+quantum",      // unknown second type
            "local48*1.5",                // fraction out of range
            "local48*-0.1",               // negative fraction
            "local48*half",               // non-numeric fraction
            "local48*0.3:slow=2",         // missing the 'x'
            "local48*0.3:slow=0.5x",      // factor < 1
            "local48*0.3:slow=manyx",     // non-numeric factor
            "local48:slow=2x",            // factor without a fraction
        ] {
            assert!(FleetSpec::parse(bad).is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn slow_count_rounds_and_clamps() {
        let fleet = FleetSpec::parse("local48*0.5").unwrap();
        assert_eq!(fleet.slow_count(0), 0);
        assert_eq!(fleet.slow_count(1), 1); // round(0.5) = 1
        assert_eq!(fleet.slow_count(3), 2); // round(1.5) = 2
        let all = FleetSpec::parse("local48*1").unwrap();
        assert_eq!(all.slow_count(7), 7);
    }

    #[test]
    fn uniform_price_is_linear_in_m() {
        let fleet = FleetSpec::uniform(HardwareProfile::ideal());
        let unit = HardwareProfile::ideal().price_per_machine_second;
        for m in [1usize, 2, 32] {
            assert!((fleet.price_rate(m) - unit * m as f64).abs() < 1e-15);
        }
        assert_eq!(fleet.price_rate(0), 0.0);
    }
}

//! `hemingway` — CLI for the Hemingway reproduction.
//!
//! Subcommands:
//!   run              run one (algorithm, machines) configuration
//!   sweep            run an algorithm across the machine grid
//!   fit-system       profile + fit the Ernest model f(m)
//!   fit-convergence  fit the convergence model g(i, m) from a sweep
//!   fit              fit + persist advisor model artifacts (models/*.json)
//!   advise           answer the paper's two query types from artifacts
//!   serve            long-lived advisor: JSON queries on stdin (or TCP with --tcp)
//!   serve-load       load-generate against a running TCP advisor server
//!   adaptive         the Fig 2 adaptive reconfiguration loop
//!   elastic          one run under a failure scenario with advisor re-planning
//!   repro            regenerate a paper figure/table (or `all`)
//!   info             engine/artifact diagnostics

use hemingway::advisor::{
    adaptive_cocoa_plus, run_elastic, AdaptiveConfig, AlgorithmId, Constraints, DataFilter,
    ElasticConfig, FleetFilter, ModeFilter, Query, WorkloadFilter,
};
use hemingway::cluster::{BarrierMode, BspSim, ClusterSim, FleetSpec, Scenario};
use hemingway::optim::Objective;
use hemingway::config::ExperimentConfig;
use hemingway::repro::common::{load_or_fit_registry, update_summary_file};
use hemingway::repro::{run_figures, ReproContext, FIGURES};
use hemingway::sweep::SweepGrid;
use hemingway::util::cli::Args;
use hemingway::util::logger;

fn main() {
    logger::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print_help();
        return;
    }
    let cmd = argv[0].clone();
    let args = Args::parse(argv.into_iter().skip(1));
    if args.flag("verbose") {
        logger::set_level(logger::Level::Debug);
    }
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "hemingway — modeling distributed optimization algorithms (Pan et al. 2017)\n\n\
         usage: hemingway <command> [options]\n\n\
         commands:\n\
         \x20 run              --algo cocoa+ --machines 16 [--config f.json] [--native]\n\
         \x20 sweep            --algo cocoa+ [--seeds N] [--threads K] [--barrier MODE]\n\
         \x20                  [--staleness-grid 0,2,8] [--fleets F,..] [--data D,..]\n\
         \x20                  [--workloads hinge,logistic,ridge] [--resume] [--native]\n\
         \x20 fit-system       --algo cocoa+ [--native]\n\
         \x20 fit-convergence  --algo cocoa+ [--native]\n\
         \x20 fit              [--algos cocoa+,cocoa] [--barriers bsp,ssp:4,async]\n\
         \x20                  [--fleets local48,straggly48] [--workloads W,..]\n\
         \x20                  [--data dense,sparse:0.01,..] [--native]\n\
         \x20 calibrate        [--name N] [--quick] [--out DIR]  run on-host\n\
         \x20                  microbenchmarks, fit a measured hardware profile,\n\
         \x20                  write <out_dir>/calib/<N>.json (hemingway-calib/v1)\n\
         \x20 advise           --eps 1e-4 --budget 20 [--max-machines M] [--cost-weight W]\n\
         \x20                  [--barrier MODE|any] [--fleet SPEC|base|any]\n\
         \x20                  [--workload hinge|logistic|ridge|base|any]\n\
         \x20                  [--data SCENARIO|base|any] [--native]\n\
         \x20 serve            [--algos ...] [--barriers ...] [--fleets ...]\n\
         \x20                  [--workloads ...] [--data ...] [--native]\n\
         \x20                  JSON queries on stdin\n\
         \x20                  [--tcp <addr>] [--workers N] [--reload-ms MS]\n\
         \x20                  [--port-file <f>]  threaded TCP server instead of stdin\n\
         \x20 serve-load       --addr <host:port> [--clients N] [--queries M]\n\
         \x20                  [--json <f>] [--shutdown]  load-generate against a server\n\
         \x20 adaptive         [--frames 8] [--frame-seconds 5] [--native]\n\
         \x20 elastic          --scenario pool=16,preempt@5x12 [--algo cocoa+]\n\
         \x20                  [--machines 16] [--replan-every 5] [--native]\n\
         \x20                  advisor-driven checkpoint/resize under failure events\n\
         \x20 repro            --figure <id>|all [--native]\n\
         \x20 info\n\n\
         figure ids: {}\n\n\
         common options:\n\
         \x20 --config <file>   JSON experiment config (see configs/default.json)\n\
         \x20 --profile-dir <d> load measured hemingway-calib/v1 profiles; name them\n\
         \x20                  as measured:<name> in profile/fleet specs\n\
         \x20 --native          use the native backend instead of PJRT/HLO\n\
         \x20 --seeds <N>       seed replicates per sweep cell (mean±std aggregation)\n\
         \x20 --threads <K>     sweep worker threads (default: HEMINGWAY_THREADS or cores)\n\
         \x20 --barriers <M,..> barrier modes to fit/serve (bsp, ssp:<staleness>, async)\n\
         \x20 --fleets <F,..>   fleets to sweep/fit/serve: a profile (local48), a shaped\n\
         \x20                  fleet (local48*0.25:slow=3x), a mix (mixed:r3_xlarge+local48)\n\
         \x20                  or a preset (mixed48, straggly48); first entry = base fleet\n\
         \x20 --workloads <W,..> objectives to sweep/fit/serve (hinge, logistic, ridge);\n\
         \x20                  first entry = base workload (default: hinge)\n\
         \x20 --data <D,..>    data scenarios to sweep/fit/serve: dense, sparse:<density>,\n\
         \x20                  pos:<rate>, skew:<s> (parts joined with '+'); first entry =\n\
         \x20                  base scenario; for advise, one scenario, 'base' or 'any'\n\
         \x20 --resume         (sweep) report how many cells the trace store already\n\
         \x20                  holds, then run only the remainder\n\
         \x20 --verbose         debug logging (or HEMINGWAY_LOG=debug)\n\n\
         `fit` writes <out_dir>/models/*.json; `advise` and `serve` load them\n\
         (fit-on-miss) and detect stale artifacts via the config hash.\n\
         Queries default to barrier mode 'bsp' on the base fleet, base\n\
         workload and base data scenario; pass --barrier any / --fleet any /\n\
         --workload any / --data any (or wire \"barrier_mode\"/\"fleet\"/\n\
         \"workload\"/\"data\" fields) to search over every fitted variant. The serve loop also answers\n\
         {{\"query\":\"cheapest_to\",\"eps\":…}} in real fleet dollars, plus\n\
         {{\"query\":\"stats\"}} (qps + latency percentiles) and\n\
         {{\"query\":\"shutdown\"}} (graceful drain). With --tcp the same\n\
         protocol runs over newline-JSON TCP; --reload-ms polls the model\n\
         artifact dir and hot-swaps freshly fitted models (0 disables).",
        FIGURES.join(", ")
    );
}

fn load_cfg(args: &Args) -> hemingway::Result<ExperimentConfig> {
    // Measured-profile artifacts register before the config parses:
    // a config (or --fleets below) naming `measured:<n>` validates its
    // fleet grammar eagerly and needs the registry populated first.
    if let Some(dir) = args.get("profile-dir") {
        let names = hemingway::calib::load_profile_dir(std::path::Path::new(dir))?;
        hemingway::log_info!("loaded {} measured profile(s): {}", names.len(), names.join(", "));
    }
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(dir) = args.get("profile-dir") {
        if cfg.profile_dir.is_empty() {
            cfg.profile_dir = dir.to_string();
        }
    }
    if let Some(ms) = args.get("machines-grid") {
        cfg.machines = ms
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|e| hemingway::err!("bad --machines-grid: {e}"))?;
    }
    if let Some(bs) = args.get("barriers") {
        cfg.barrier_modes = bs
            .split(',')
            .map(BarrierMode::parse)
            .collect::<hemingway::Result<_>>()?;
        hemingway::ensure!(!cfg.barrier_modes.is_empty(), "--barriers lists no modes");
    }
    if let Some(fs) = args.get("fleets") {
        cfg.fleets = fs
            .split(',')
            .map(|s| {
                let s = s.trim();
                FleetSpec::parse(s)?; // strict: fail fast on typos
                Ok(s.to_string())
            })
            .collect::<hemingway::Result<_>>()?;
        hemingway::ensure!(!cfg.fleets.is_empty(), "--fleets lists no fleets");
    }
    if let Some(ws) = args.get("workloads") {
        cfg.workloads = ws
            .split(',')
            .map(Objective::parse)
            .collect::<hemingway::Result<_>>()?;
        hemingway::ensure!(!cfg.workloads.is_empty(), "--workloads lists no objectives");
    }
    if let Some(ds) = args.get("data") {
        // `advise` reuses --data as its query filter; the filter-only
        // spellings ('base', 'any') name no scenario axis to fit on.
        if ds.trim() != "base" && ds.trim() != "any" {
            cfg.data_scenarios = ds
                .split(',')
                .map(hemingway::data::DataScenario::canonical)
                .collect::<hemingway::Result<_>>()?;
            hemingway::ensure!(!cfg.data_scenarios.is_empty(), "--data lists no scenarios");
        }
    }
    Ok(cfg)
}

/// The algorithms a fit/advise/serve invocation targets: `--algos`
/// (comma-separated) or the config's `algorithms` list.
fn parse_algos(args: &Args, cfg: &ExperimentConfig) -> hemingway::Result<Vec<AlgorithmId>> {
    let defaults: Vec<&str> = cfg.algorithms.iter().map(String::as_str).collect();
    let algos: Vec<AlgorithmId> = args
        .str_list_or("algos", &defaults)
        .iter()
        .map(|s| AlgorithmId::parse(s))
        .collect::<hemingway::Result<_>>()?;
    hemingway::ensure!(!algos.is_empty(), "no algorithms selected (--algos or config)");
    Ok(algos)
}

fn dispatch(cmd: &str, args: &Args) -> hemingway::Result<()> {
    let native = args.flag("native");
    match cmd {
        "run" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let machines = args.usize_or("machines", 16)?;
            let ctx = ReproContext::new(cfg, native)?;
            let trace = ctx.run_one(&algo, machines)?;
            let mut set = hemingway::optim::TraceSet::default();
            set.push(trace);
            let path = ctx.out_dir.join(format!("run_{algo}_m{machines}.csv"));
            set.write(&path)?;
            println!("wrote {}", path.display());
        }
        "sweep" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let seeds = args.usize_or("seeds", 1)?.max(1);
            let threads = args.usize_or("threads", 0)?; // 0 = auto
            // The barrier-mode axis: an explicit staleness grid
            // (ssp:k per entry), a single --barrier mode, or BSP. The
            // two flags would contradict each other, so together they
            // are an error rather than one silently winning.
            let modes: Vec<BarrierMode> = match (args.get("staleness-grid"), args.get("barrier"))
            {
                (Some(_), Some(_)) => hemingway::bail!(
                    "--barrier and --staleness-grid are mutually exclusive \
                     (a staleness grid already names its modes)"
                ),
                (Some(sg), None) => sg
                    .split(',')
                    .map(|s| {
                        s.trim()
                            .parse::<usize>()
                            .map(|staleness| BarrierMode::Ssp { staleness })
                            .map_err(|_| {
                                hemingway::err!("--staleness-grid: bad integer '{s}'")
                            })
                    })
                    .collect::<hemingway::Result<_>>()?,
                (None, barrier) => vec![BarrierMode::parse(barrier.unwrap_or("bsp"))?],
            };
            let mut ctx = ReproContext::new(cfg, native)?;
            if threads > 0 {
                ctx.sweep.threads = threads;
            }
            let grid = SweepGrid {
                algorithms: vec![algo.clone()],
                machines: ctx.cfg.machines.clone(),
                modes,
                fleets: ctx.cfg.fleets.clone(),
                workloads: ctx.cfg.workloads.clone(),
                data: ctx.cfg.data_scenarios.clone(),
                events: String::new(),
                seeds,
                base_seed: ctx.cfg.seed,
                run: ctx.run_config(),
            };
            let cells = grid.cells();
            if args.flag("resume") {
                // Manifest-backed: counts membership, loads no traces.
                let context_key = ctx.grid_context_key(&grid);
                let plan = ctx.sweep.plan(&context_key, &cells);
                println!(
                    "resume: {}/{} cells already in the trace store; {} to run",
                    plan.done,
                    plan.total,
                    plan.remaining()
                );
            }
            ctx.sweep.progress = true;

            // Stream: each finished trace is folded into the aggregate
            // (and, for replicate 0, the long-format CSV) and dropped —
            // peak residency is O(groups), not O(cells).
            let t0 = std::time::Instant::now();
            let mut set = hemingway::optim::TraceSet::default();
            let mut agg = hemingway::sweep::StreamAggregator::new(ctx.cfg.target_subopt);
            let mut n_cells = 0usize;
            ctx.run_grid_stream(&grid, &mut |i, trace| {
                n_cells += 1;
                agg.push(&trace);
                if cells[i].replicate == 0 {
                    set.push(trace);
                }
                Ok(())
            })?;
            let (hits, misses) = ctx.sweep.cache.stats();
            println!(
                "{n_cells} cells in {:.1}s wall ({} threads, cache: {hits} hits / {misses} misses)",
                t0.elapsed().as_secs_f64(),
                ctx.sweep.threads
            );

            // Replicate-0 traces keep the historical long-format CSV.
            let path = ctx.out_dir.join(format!("sweep_{algo}.csv"));
            set.write(&path)?;
            println!("wrote {}", path.display());

            // Seed-replication aggregate: mean ± stddev per cell.
            let aggs = agg.finish();
            let mut agg_table = hemingway::util::csv::Table::new(&[
                "machines",
                "barrier",
                "fleet",
                "workload",
                "data",
                "replicates",
                "reached",
                "iters_mean",
                "iters_std",
                "time_mean",
                "time_std",
                "final_subopt_mean",
                "final_subopt_std",
                "iter_time_mean",
                "iter_time_std",
            ]);
            for a in &aggs {
                // The fleet column holds the index into the sweep's
                // fleet axis (0 = the base/default fleet).
                let fleet_idx = grid
                    .fleets
                    .iter()
                    .position(|f| *f == a.fleet)
                    .unwrap_or(0);
                // Likewise the data column: index into the grid's data
                // axis (0 = the base, or the implicit dense scenario).
                let data_idx = grid
                    .data
                    .iter()
                    .position(|d| *d == a.data)
                    .unwrap_or(0);
                agg_table.push(vec![
                    a.machines as f64,
                    a.barrier_mode.csv_id(),
                    fleet_idx as f64,
                    a.workload.csv_id(),
                    data_idx as f64,
                    a.replicates as f64,
                    a.reached as f64,
                    a.iters_to_target.mean,
                    a.iters_to_target.std,
                    a.time_to_target.mean,
                    a.time_to_target.std,
                    a.final_subopt.mean,
                    a.final_subopt.std,
                    a.mean_iter_time.mean,
                    a.mean_iter_time.std,
                ]);
                println!(
                    "  m={:<4} {:<7} {:<12} {:<8} {:<8} reached {}/{}  iters-to-{:.0e} {}  iter-time {}s",
                    a.machines,
                    a.barrier_mode.as_str(),
                    if a.fleet.is_empty() { "-" } else { a.fleet.as_str() },
                    a.workload.as_str(),
                    if a.data.is_empty() { "-" } else { a.data.as_str() },
                    a.reached,
                    a.replicates,
                    ctx.cfg.target_subopt,
                    if a.reached > 0 {
                        a.iters_to_target.display(1)
                    } else {
                        "-".to_string()
                    },
                    a.mean_iter_time.display(4),
                );
            }
            let agg_path = ctx.out_dir.join(format!("sweep_{algo}_agg.csv"));
            agg_table.write(&agg_path)?;
            println!("wrote {}", agg_path.display());
        }
        "fit-system" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let ctx = ReproContext::new(cfg, native)?;
            let model = ctx.fit_ernest(&algo)?;
            println!(
                "Ernest model for {algo}: f(m) = {:.4} + {:.3e}·(size/m) + {:.4}·log m + {:.5}·m",
                model.theta[0], model.theta[1], model.theta[2], model.theta[3]
            );
            for &m in &ctx.cfg.machines {
                println!(
                    "  f({m:<4}) = {:.4}s",
                    model.predict(m, ctx.problem.data.n as f64)
                );
            }
        }
        "fit-convergence" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let ctx = ReproContext::new(cfg, native)?;
            let traces = ctx.run_sweep(&algo)?;
            let pts = hemingway::hemingway_model::points_from_traces(&traces.traces);
            let model = hemingway::hemingway_model::ConvergenceModel::fit(
                &pts,
                hemingway::hemingway_model::FeatureLibrary::standard(),
                ctx.cfg.seed,
            )?;
            println!(
                "convergence model for {algo}: R² = {:.4} on {} points",
                model.train_r2, model.n_train
            );
            println!("selected features:");
            for (name, coef) in model.selected_features() {
                println!("  {name:<22} {coef:+.5}");
            }
        }
        "fit" => {
            let cfg = load_cfg(args)?;
            let algos = parse_algos(args, &cfg)?;
            let context = cfg.model_context_hash(native);
            let detail = cfg.model_context(native);
            let dir = hemingway::repro::common::models_dir(&cfg);
            let ctx = ReproContext::new(cfg, native)?;
            for algo in algos {
                let model = ctx.fit_combined(algo)?;
                let path = hemingway::advisor::artifact_path(&dir, algo);
                hemingway::advisor::save_artifact(&path, algo, &context, &detail, &model)?;
                println!(
                    "wrote {} (context {context}, conv R²={:.4})",
                    path.display(),
                    model.conv.train_r2
                );
            }
        }
        "calibrate" => {
            let cfg = load_cfg(args)?;
            let name = args.str_or("name", "host").to_string();
            let quick = args.flag("quick");
            let out_dir = match args.get("out") {
                Some(d) => std::path::PathBuf::from(d),
                None => std::path::Path::new(&cfg.out_dir).join("calib"),
            };
            println!(
                "calibrating '{name}' ({} suite; timing real kernels, threadpool, loopback TCP)…",
                if quick { "quick" } else { "full" }
            );
            let samples = hemingway::calib::run_suite(quick)?;
            let fit = hemingway::calib::fit_measured(&name, &samples)?;
            let artifact = hemingway::calib::CalibArtifact {
                name: name.clone(),
                host: samples.host.clone(),
                profile: fit.profile.clone(),
                compute_rmse: fit.compute_rmse,
                sched_rmse: fit.sched_rmse,
                net_rmse: fit.net_rmse,
                compute_samples: samples.compute.len(),
                sched_samples: samples.sched.len(),
                net_samples: samples.net.len(),
                wall_seconds: samples.wall_seconds,
            };
            let path = artifact.save(&out_dir)?;
            let p = &artifact.profile;
            println!("host {}  ({:.1}s of microbenchmarks)", samples.host.summary(), samples.wall_seconds);
            println!("  flops_per_sec      {:.3e}  (rmse {:.2e}s over {} samples)",
                p.flops_per_sec, artifact.compute_rmse, artifact.compute_samples);
            println!("  iteration_overhead {:.4}s + {:.5}s/machine  (rmse {:.2e}s over {} samples)",
                p.iteration_overhead, p.sched_per_machine, artifact.sched_rmse, artifact.sched_samples);
            println!("  net_latency        {:.5}s, bandwidth {:.3e} B/s  (rmse {:.2e}s over {} samples)",
                p.net_latency, p.net_bandwidth, artifact.net_rmse, artifact.net_samples);
            println!("  noise_sigma        {:.4}  (straggler/price fields carried from local48)",
                p.noise_sigma);
            println!(
                "wrote {} (generation {})\nuse it with:  --profile-dir {}  and profile/fleet 'measured:{name}'",
                path.display(),
                artifact.generation(),
                out_dir.display()
            );
        }
        "advise" => {
            let cfg = load_cfg(args)?;
            let eps = args.f64_or("eps", cfg.target_subopt)?;
            let budget = args.f64_or("budget", 20.0)?;
            let constraints = Constraints {
                max_machines: match args.get("max-machines") {
                    Some(_) => Some(args.usize_or("max-machines", 0)?),
                    None => None,
                },
                machine_cost_weight: args.f64_or("cost-weight", 0.0)?,
                barrier_mode: ModeFilter::parse(args.str_or("barrier", "bsp"))?,
                fleet: FleetFilter::parse(args.str_or("fleet", "base"))?,
                workload: WorkloadFilter::parse(args.str_or("workload", "base"))?,
                data: match args.get("data") {
                    // A comma-separated list names the fit axis (parsed
                    // in load_cfg); searching then spans every fitted
                    // scenario rather than pinning one.
                    Some(d) if d.contains(',') => DataFilter::Any,
                    Some(d) => DataFilter::parse(d)?,
                    None => DataFilter::Base,
                },
            };
            constraints.validate()?;
            let algos = parse_algos(args, &cfg)?;
            let registry = load_or_fit_registry(&cfg, native, &algos)?;
            let fleet_tag = |fleet: &str| {
                if fleet.is_empty() {
                    String::new()
                } else {
                    format!(" fleet={fleet}")
                }
            };
            let workload_tag = |workload: Objective| {
                if workload.is_hinge() {
                    String::new()
                } else {
                    format!(" workload={workload}")
                }
            };
            let data_tag = |data: &str| {
                if data.is_empty() {
                    String::new()
                } else {
                    format!(" data={data}")
                }
            };
            match registry.answer(&Query::FastestTo { eps, constraints: constraints.clone() }) {
                Some(rec) => println!(
                    "fastest to {eps:.0e}:   {} m={} [{}]{}{}{} → {:.2} predicted seconds",
                    rec.algorithm,
                    rec.machines,
                    rec.barrier_mode,
                    fleet_tag(&rec.fleet),
                    workload_tag(rec.workload),
                    data_tag(&rec.data),
                    rec.predicted.value()
                ),
                None => println!("fastest to {eps:.0e}:   no configuration reaches the target"),
            }
            match registry.answer(&Query::BestAt { budget, constraints: constraints.clone() }) {
                Some(rec) => println!(
                    "best loss in {budget}s: {} m={} [{}]{}{}{} → {:.2e} predicted suboptimality",
                    rec.algorithm,
                    rec.machines,
                    rec.barrier_mode,
                    fleet_tag(&rec.fleet),
                    workload_tag(rec.workload),
                    data_tag(&rec.data),
                    rec.predicted.value()
                ),
                None => println!("best loss in {budget}s: no feasible configuration"),
            }
            // Dollars only rank cleanly without the abstract cost
            // weight (cheapest_to refuses to mix the two).
            if constraints.machine_cost_weight == 0.0 {
                match registry
                    .answer(&Query::CheapestTo { eps, constraints: constraints.clone() })
                {
                    Some(rec) => println!(
                        "cheapest to {eps:.0e}:  {} m={} [{}]{}{}{} → ${:.4} predicted",
                        rec.algorithm,
                        rec.machines,
                        rec.barrier_mode,
                        fleet_tag(&rec.fleet),
                        workload_tag(rec.workload),
                        data_tag(&rec.data),
                        rec.predicted.value()
                    ),
                    None => println!("cheapest to {eps:.0e}:  no priceable configuration"),
                }
            }
            println!("\nprediction table (algorithm × m × mode × fleet × workload × data):");
            for row in registry.table(eps, budget, &constraints) {
                println!(
                    "  {:<13} m={:<4} {:<7}{:<14}{:<10}{:<12} time-to-ε {:<10} subopt@{budget}s {:.3e}",
                    row.algorithm,
                    row.machines,
                    row.barrier_mode.as_str(),
                    fleet_tag(&row.fleet),
                    workload_tag(row.workload),
                    data_tag(&row.data),
                    row.time_to_eps
                        .map(|t| format!("{t:.2}s"))
                        .unwrap_or_else(|| "-".into()),
                    row.subopt_at_budget
                );
            }
        }
        "serve" => {
            let cfg = load_cfg(args)?;
            let algos = parse_algos(args, &cfg)?;
            let registry = load_or_fit_registry(&cfg, native, &algos)?;
            if let Some(addr) = args.get("tcp") {
                let workers = args.usize_or(
                    "workers",
                    hemingway::util::threadpool::default_threads(),
                )?;
                let reload_ms = args.u64_or("reload-ms", 2000)?;
                let reload = if reload_ms > 0 {
                    Some(hemingway::advisor::ReloadConfig {
                        dir: hemingway::repro::common::models_dir(&cfg),
                        expect_context: Some(cfg.model_context_hash(native)),
                        machine_grid: cfg.machines.clone(),
                        iter_cap: cfg.advisor_iter_cap,
                        fleets: cfg.fleet_specs()?,
                        calibration: hemingway::calib::calibration_json(
                            &cfg.profile,
                            &cfg.fleets,
                        ),
                        algos: Some(algos.clone()),
                        poll: std::time::Duration::from_millis(reload_ms),
                    })
                } else {
                    None
                };
                let server = hemingway::advisor::AdvisorServer::bind(
                    addr,
                    registry,
                    hemingway::advisor::ServerConfig {
                        workers,
                        queue_capacity: (workers * 4).max(4),
                        reload,
                    },
                )?;
                let local = server.local_addr();
                println!("listening on {local}");
                std::io::Write::flush(&mut std::io::stdout())?;
                // Scripts starting the server on an ephemeral port
                // (--tcp 127.0.0.1:0) read the resolved address here.
                if let Some(path) = args.get("port-file") {
                    std::fs::write(path, format!("{local}\n"))?;
                }
                hemingway::advisor::install_sigint_handler();
                server.run()?;
            } else {
                eprintln!(
                    "serving {} model(s); one JSON query per line, e.g. \
                     {{\"query\":\"fastest_to\",\"eps\":1e-4}} — Ctrl-D to stop",
                    registry.len()
                );
                let stdin = std::io::stdin();
                let stdout = std::io::stdout();
                let stats = hemingway::advisor::serve(&registry, stdin.lock(), stdout.lock())?;
                hemingway::log_info!("{}", stats.summary());
            }
        }
        "serve-load" => {
            let addr = args
                .get("addr")
                .ok_or_else(|| hemingway::err!("serve-load needs --addr host:port"))?
                .to_string();
            let clients = args.usize_or("clients", 4)?;
            let queries = args.usize_or("queries", 200)?;
            let load_cfg = hemingway::advisor::LoadConfig::new(addr.clone(), clients, queries);
            let report = hemingway::advisor::run_load(&load_cfg)?;
            println!("{}", report.summary());
            // The server-side view of the same burst.
            let stats = hemingway::advisor::send_control(&addr, r#"{"query":"stats"}"#)?;
            println!("{stats}");
            if let Some(path) = args.get("json") {
                std::fs::write(path, report.to_json().to_pretty())?;
            }
            if args.flag("shutdown") {
                let resp = hemingway::advisor::send_control(&addr, r#"{"query":"shutdown"}"#)?;
                println!("{resp}");
            }
        }
        "adaptive" => {
            let cfg = load_cfg(args)?;
            let frames = args.usize_or("frames", 8)?;
            let frame_seconds = args.f64_or("frame-seconds", 5.0)?;
            let ctx = ReproContext::new(cfg, native)?;
            let mut sim = BspSim::new(ctx.profile.clone(), ctx.cfg.seed);
            let backend = ctx.backend();
            let a_cfg = AdaptiveConfig::from_experiment(&ctx.cfg, frame_seconds, frames);
            let run =
                adaptive_cocoa_plus(&ctx.problem, backend.as_ref(), &mut sim, ctx.p_star, &a_cfg)?;
            println!("adaptive CoCoA+ (Fig 2 loop):");
            for f in &run.frames {
                println!(
                    "  frame {} m={:<4} iters={:<4} subopt {:.3e} → {:.3e} (t={:.1}s){}",
                    f.frame,
                    f.machines,
                    f.iterations,
                    f.start_subopt,
                    f.end_subopt,
                    f.sim_time_end,
                    if f.model_driven { " [model-driven]" } else { "" }
                );
            }
            println!(
                "final subopt {:.3e} in {:.1}s simulated",
                run.final_subopt, run.total_time
            );
        }
        "elastic" => {
            let cfg = load_cfg(args)?;
            let algo = AlgorithmId::parse(args.str_or("algo", "cocoa+"))?;
            let machines = args.usize_or("machines", 16)?;
            let replan_every = args.usize_or("replan-every", 5)?;
            let spec = args.str_or("scenario", "").to_string();
            hemingway::ensure!(
                !spec.is_empty(),
                "elastic needs --scenario (e.g. pool=16,preempt@5x12)"
            );
            let scenario = Scenario::parse(&spec)?;
            let registry = load_or_fit_registry(&cfg, native, &[algo])?;
            let ctx = ReproContext::new(cfg, native)?;
            let backend = ctx.backend();
            let fleet = ctx.fleet_for(&ctx.base_fleet_name())?;
            // Seeded like the corresponding sweep cell so the run is
            // comparable against a cached static trace.
            let mut sim =
                ClusterSim::with_fleet(fleet, BarrierMode::Bsp, ctx.cfg.seed ^ machines as u64)
                    .with_scenario(&scenario);
            let mut algo_box = hemingway::optim::by_name(
                algo.as_str(),
                &ctx.problem,
                machines,
                ctx.cfg.seed as u32,
            )?;
            let e_cfg = ElasticConfig {
                replan_every,
                machine_grid: ctx.cfg.machines.clone(),
                seed: ctx.cfg.seed as u32,
            };
            let run_cfg = ctx.run_config();
            let run = run_elastic(
                &mut algo_box,
                backend.as_ref(),
                &ctx.problem,
                &mut sim,
                ctx.p_star,
                &run_cfg,
                &e_cfg,
                Some(&registry),
            )?;
            println!("elastic {algo} m={machines} under '{spec}' (replan every {replan_every}):");
            for (t, ev) in sim.fired() {
                println!("  event  t={t:<8.2} {ev}");
            }
            for r in &run.replans {
                println!(
                    "  replan t={:<8.2} iter={:<4} m {}→{} {}",
                    r.sim_time,
                    r.iter,
                    r.from_machines,
                    r.to_machines,
                    if r.moved { "[checkpointed move]" } else { "[stayed]" }
                );
            }
            let last = run.trace.records.last().expect("trace has records");
            println!(
                "final subopt {:.3e} at t={:.1}s ({} iterations, {} move(s))",
                run.trace.final_subopt(),
                last.sim_time,
                last.iter,
                run.replans.iter().filter(|r| r.moved).count()
            );
        }
        "repro" => {
            let cfg = load_cfg(args)?;
            let which = args.str_or("figure", "all").to_string();
            let ctx = ReproContext::new(cfg, native)?;
            let summaries = run_figures(&ctx, &which)?;
            println!("== summaries ==");
            for s in &summaries {
                println!("  {s}");
            }
            // Merge into out/summaries.txt for EXPERIMENTS.md assembly
            // (replaces each figure's previous line; re-runs don't
            // accumulate duplicates).
            update_summary_file(&ctx.out_dir.join("summaries.txt"), &summaries)?;
        }
        "info" => {
            let engine =
                hemingway::runtime::Engine::new(&hemingway::runtime::default_artifact_dir())?;
            let m = engine.manifest();
            println!(
                "artifacts: {} (n={} d={} machines {:?})",
                m.artifacts.len(),
                m.n,
                m.d,
                m.machines
            );
            for a in &m.artifacts {
                println!(
                    "  {:<14} n_loc={:<6} h={:<6} {}",
                    a.kernel, a.n_loc, a.h_steps, a.file
                );
            }
        }
        other => {
            print_help();
            hemingway::bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

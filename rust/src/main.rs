//! `hemingway` — CLI for the Hemingway reproduction.
//!
//! Subcommands:
//!   run              run one (algorithm, machines) configuration
//!   sweep            run an algorithm across the machine grid
//!   fit-system       profile + fit the Ernest model f(m)
//!   fit-convergence  fit the convergence model g(i, m) from a sweep
//!   advise           answer the paper's two query types
//!   adaptive         the Fig 2 adaptive reconfiguration loop
//!   repro            regenerate a paper figure/table (or `all`)
//!   info             engine/artifact diagnostics

use hemingway::advisor::{adaptive_cocoa_plus, AdaptiveConfig};
use hemingway::cluster::BspSim;
use hemingway::config::ExperimentConfig;
use hemingway::repro::{run_figures, ReproContext, FIGURES};
use hemingway::sweep::SweepGrid;
use hemingway::util::cli::Args;
use hemingway::util::logger;

fn main() {
    logger::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print_help();
        return;
    }
    let cmd = argv[0].clone();
    let args = Args::parse(argv.into_iter().skip(1));
    if args.flag("verbose") {
        logger::set_level(logger::Level::Debug);
    }
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "hemingway — modeling distributed optimization algorithms (Pan et al. 2017)\n\n\
         usage: hemingway <command> [options]\n\n\
         commands:\n\
         \x20 run              --algo cocoa+ --machines 16 [--config f.json] [--native]\n\
         \x20 sweep            --algo cocoa+ [--seeds N] [--threads K] [--native]\n\
         \x20 fit-system       --algo cocoa+ [--native]\n\
         \x20 fit-convergence  --algo cocoa+ [--native]\n\
         \x20 advise           --eps 1e-4 --budget 20 [--native]\n\
         \x20 adaptive         [--frames 8] [--frame-seconds 5] [--native]\n\
         \x20 repro            --figure <id>|all [--native]\n\
         \x20 info\n\n\
         figure ids: {}\n\n\
         common options:\n\
         \x20 --config <file>   JSON experiment config (see configs/default.json)\n\
         \x20 --native          use the native backend instead of PJRT/HLO\n\
         \x20 --seeds <N>       seed replicates per sweep cell (mean±std aggregation)\n\
         \x20 --threads <K>     sweep worker threads (default: HEMINGWAY_THREADS or cores)\n\
         \x20 --verbose         debug logging (or HEMINGWAY_LOG=debug)",
        FIGURES.join(", ")
    );
}

fn load_cfg(args: &Args) -> hemingway::Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::load(std::path::Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(ms) = args.get("machines-grid") {
        cfg.machines = ms
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<_, _>>()
            .map_err(|e| hemingway::err!("bad --machines-grid: {e}"))?;
    }
    Ok(cfg)
}

fn dispatch(cmd: &str, args: &Args) -> hemingway::Result<()> {
    let native = args.flag("native");
    match cmd {
        "run" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let machines = args.usize_or("machines", 16)?;
            let ctx = ReproContext::new(cfg, native)?;
            let trace = ctx.run_one(&algo, machines)?;
            let mut set = hemingway::optim::TraceSet::default();
            set.push(trace);
            let path = ctx.out_dir.join(format!("run_{algo}_m{machines}.csv"));
            set.write(&path)?;
            println!("wrote {}", path.display());
        }
        "sweep" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let seeds = args.usize_or("seeds", 1)?.max(1);
            let threads = args.usize_or("threads", 0)?; // 0 = auto
            let mut ctx = ReproContext::new(cfg, native)?;
            if threads > 0 {
                ctx.sweep.threads = threads;
            }
            let grid = SweepGrid {
                algorithms: vec![algo.clone()],
                machines: ctx.cfg.machines.clone(),
                seeds,
                base_seed: ctx.cfg.seed,
                run: ctx.run_config(),
            };
            let t0 = std::time::Instant::now();
            let traces = ctx.run_grid(&grid)?;
            let (hits, misses) = ctx.sweep.cache.stats();
            println!(
                "{} cells in {:.1}s wall ({} threads, cache: {hits} hits / {misses} misses)",
                traces.len(),
                t0.elapsed().as_secs_f64(),
                ctx.sweep.threads
            );

            // Replicate-0 traces keep the historical long-format CSV.
            let mut set = hemingway::optim::TraceSet::default();
            for (cell, trace) in grid.cells().iter().zip(&traces) {
                if cell.replicate == 0 {
                    set.push(trace.clone());
                }
            }
            let path = ctx.out_dir.join(format!("sweep_{algo}.csv"));
            set.write(&path)?;
            println!("wrote {}", path.display());

            // Seed-replication aggregate: mean ± stddev per cell.
            let aggs = hemingway::sweep::aggregate(&traces, ctx.cfg.target_subopt);
            let mut agg_table = hemingway::util::csv::Table::new(&[
                "machines",
                "replicates",
                "reached",
                "iters_mean",
                "iters_std",
                "time_mean",
                "time_std",
                "final_subopt_mean",
                "final_subopt_std",
                "iter_time_mean",
                "iter_time_std",
            ]);
            for a in &aggs {
                agg_table.push(vec![
                    a.machines as f64,
                    a.replicates as f64,
                    a.reached as f64,
                    a.iters_to_target.mean,
                    a.iters_to_target.std,
                    a.time_to_target.mean,
                    a.time_to_target.std,
                    a.final_subopt.mean,
                    a.final_subopt.std,
                    a.mean_iter_time.mean,
                    a.mean_iter_time.std,
                ]);
                println!(
                    "  m={:<4} reached {}/{}  iters-to-{:.0e} {}  iter-time {}s",
                    a.machines,
                    a.reached,
                    a.replicates,
                    ctx.cfg.target_subopt,
                    if a.reached > 0 {
                        a.iters_to_target.display(1)
                    } else {
                        "-".to_string()
                    },
                    a.mean_iter_time.display(4),
                );
            }
            let agg_path = ctx.out_dir.join(format!("sweep_{algo}_agg.csv"));
            agg_table.write(&agg_path)?;
            println!("wrote {}", agg_path.display());
        }
        "fit-system" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let ctx = ReproContext::new(cfg, native)?;
            let model = ctx.fit_ernest(&algo)?;
            println!(
                "Ernest model for {algo}: f(m) = {:.4} + {:.3e}·(size/m) + {:.4}·log m + {:.5}·m",
                model.theta[0], model.theta[1], model.theta[2], model.theta[3]
            );
            for &m in &ctx.cfg.machines {
                println!(
                    "  f({m:<4}) = {:.4}s",
                    model.predict(m, ctx.problem.data.n as f64)
                );
            }
        }
        "fit-convergence" => {
            let cfg = load_cfg(args)?;
            let algo = args.str_or("algo", "cocoa+").to_string();
            let ctx = ReproContext::new(cfg, native)?;
            let traces = ctx.run_sweep(&algo)?;
            let pts = hemingway::hemingway_model::points_from_traces(&traces.traces);
            let model = hemingway::hemingway_model::ConvergenceModel::fit(
                &pts,
                hemingway::hemingway_model::FeatureLibrary::standard(),
                ctx.cfg.seed,
            )?;
            println!(
                "convergence model for {algo}: R² = {:.4} on {} points",
                model.train_r2, model.n_train
            );
            println!("selected features:");
            for (name, coef) in model.selected_features() {
                println!("  {name:<22} {coef:+.5}");
            }
        }
        "advise" => {
            let cfg = load_cfg(args)?;
            let ctx = ReproContext::new(cfg, native)?;
            let fit = hemingway::repro::fig3::sweep_and_fit(&ctx)?;
            let summary = hemingway::repro::tables::table_advisor(&ctx, &fit)?;
            println!("{summary}");
        }
        "adaptive" => {
            let cfg = load_cfg(args)?;
            let frames = args.usize_or("frames", 8)?;
            let frame_seconds = args.f64_or("frame-seconds", 5.0)?;
            let ctx = ReproContext::new(cfg, native)?;
            let mut sim = BspSim::new(ctx.profile.clone(), ctx.cfg.seed);
            let backend = ctx.backend();
            let a_cfg = AdaptiveConfig {
                frame_seconds,
                max_frames: frames,
                machine_grid: ctx.cfg.machines.clone(),
                target_subopt: ctx.cfg.target_subopt,
                bootstrap_machines: 16,
                seed: ctx.cfg.seed as u32,
            };
            let run =
                adaptive_cocoa_plus(&ctx.problem, backend.as_ref(), &mut sim, ctx.p_star, &a_cfg)?;
            println!("adaptive CoCoA+ (Fig 2 loop):");
            for f in &run.frames {
                println!(
                    "  frame {} m={:<4} iters={:<4} subopt {:.3e} → {:.3e} (t={:.1}s){}",
                    f.frame,
                    f.machines,
                    f.iterations,
                    f.start_subopt,
                    f.end_subopt,
                    f.sim_time_end,
                    if f.model_driven { " [model-driven]" } else { "" }
                );
            }
            println!(
                "final subopt {:.3e} in {:.1}s simulated",
                run.final_subopt, run.total_time
            );
        }
        "repro" => {
            let cfg = load_cfg(args)?;
            let which = args.str_or("figure", "all").to_string();
            let ctx = ReproContext::new(cfg, native)?;
            let summaries = run_figures(&ctx, &which)?;
            println!("== summaries ==");
            for s in &summaries {
                println!("  {s}");
            }
            // Append to out/summaries.txt for EXPERIMENTS.md assembly.
            let path = ctx.out_dir.join("summaries.txt");
            let mut text = std::fs::read_to_string(&path).unwrap_or_default();
            for s in &summaries {
                text.push_str(s);
                text.push('\n');
            }
            std::fs::write(&path, text)?;
        }
        "info" => {
            let engine =
                hemingway::runtime::Engine::new(&hemingway::runtime::default_artifact_dir())?;
            let m = engine.manifest();
            println!(
                "artifacts: {} (n={} d={} machines {:?})",
                m.artifacts.len(),
                m.n,
                m.d,
                m.machines
            );
            for a in &m.artifacts {
                println!(
                    "  {:<14} n_loc={:<6} h={:<6} {}",
                    a.kernel, a.n_loc, a.h_steps, a.file
                );
            }
        }
        other => {
            print_help();
            hemingway::bail!("unknown command '{other}'");
        }
    }
    Ok(())
}

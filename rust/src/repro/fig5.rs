//! Figures 5 & 9: forward prediction — fit on a trailing 50-iteration
//! window, predict 1 and 10 iterations ahead (paper §4.2). Fig 9 is
//! the appendix zoom to the first 100 iterations.

use super::common::ReproContext;
use super::fig3::SweepFit;
use crate::hemingway_model::forward_iterations;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

pub fn fig5(ctx: &ReproContext, fit: &SweepFit, zoom100: bool) -> crate::Result<String> {
    let tag = if zoom100 { "9" } else { "5" };
    println!("== Figure {tag}: forward prediction (+1 / +10 iterations, 50-iter window) ==");
    // The paper's panels use a single higher-m trace; take m=16.
    let trace = fit
        .traces
        .find("cocoa+", 16)
        .ok_or_else(|| crate::err!("no m=16 trace in sweep"))?;
    let mut table = Table::new(&["ahead", "iter", "true_subopt", "pred_subopt"]);
    let mut parts = Vec::new();
    // Each look-ahead refits hundreds of windowed models — run the two
    // panels concurrently through the sweep engine's thread pool.
    let aheads = [1usize, 10];
    let seed = ctx.cfg.seed;
    let panels = ctx
        .sweep
        .try_map(aheads.len(), |i| forward_iterations(trace, 50, aheads[i], seed))?;
    for (&ahead, preds) in aheads.iter().zip(&panels) {
        let mut lnerrs = Vec::new();
        let mut truth_pts = Vec::new();
        let mut pred_pts = Vec::new();
        for &(i, truth, pred) in preds {
            if zoom100 && i > 100.0 {
                continue;
            }
            table.push(vec![ahead as f64, i, truth, pred]);
            lnerrs.push((truth.ln() - pred.ln()).abs());
            truth_pts.push((i, truth));
            pred_pts.push((i, pred));
        }
        if !truth_pts.is_empty() {
            ctx.show(
                &format!("Fig {tag}: +{ahead} iterations ahead (log y)"),
                vec![
                    Series::new("true", truth_pts),
                    Series::new(format!("pred +{ahead}"), pred_pts),
                ],
                true,
                "iteration",
            );
        }
        parts.push((ahead, stats::mean(&lnerrs), lnerrs.len()));
    }
    let csv = if zoom100 {
        "fig9_forward_iter_100iters.csv"
    } else {
        "fig5_forward_iterations.csv"
    };
    ctx.write_csv(csv, &table)?;
    let err1 = parts.first().map(|p| p.1).unwrap_or(f64::NAN);
    let err10 = parts.get(1).map(|p| p.1).unwrap_or(f64::NAN);
    let summary = format!(
        "fig{tag}: forward-pred |Δln| +1: {err1:.3} ({} pts), +10: {err10:.3} ({} pts) — +1 ≤ +10: {}",
        parts.first().map(|p| p.2).unwrap_or(0),
        parts.get(1).map(|p| p.2).unwrap_or(0),
        if err1 <= err10 + 0.05 { "reproduced" } else { "NOT reproduced" }
    );
    println!("{summary}\n");
    Ok(summary)
}

//! Ablations of Hemingway's design choices (DESIGN.md §7):
//!
//! A1 — Ernest solver: NNLS (the paper's choice) vs unconstrained OLS,
//!      scored on extrapolation from small configs to large m.
//! A2 — convergence-model estimator: LassoCV (paper) vs plain OLS on
//!      the full library, scored on leave-one-m-out extrapolation.
//! A3 — feature library: full vs without the theory term family
//!      (i/m, i/m², i/√m), same LOO-m score.

use super::common::ReproContext;
use super::fig3::SweepFit;
use crate::ernest::{ErnestModel, Observation};
use crate::hemingway_model::features::{Feature, FeatureLibrary};
use crate::hemingway_model::model::{points_from_traces, ConvPoint};
use crate::hemingway_model::ConvergenceModel;
use crate::linalg::{lstsq, Matrix};
use crate::util::csv::Table;
use crate::util::stats;

/// A1: NNLS vs OLS for the Ernest fit.
fn ablate_ernest(ctx: &ReproContext) -> crate::Result<(f64, f64)> {
    let candidates = crate::ernest::design::default_candidates(16);
    let selected =
        crate::ernest::design::select_configs(&candidates, ctx.problem.data.n as f64, 10);
    let obs = ctx.profile_system("cocoa+", &selected, 8)?;

    // Held-out truth at the large configs.
    let truth = ctx.profile_system(
        "cocoa+",
        &[
            crate::ernest::design::Candidate { machines: 32, fraction: 1.0 },
            crate::ernest::design::Candidate { machines: 64, fraction: 1.0 },
            crate::ernest::design::Candidate { machines: 128, fraction: 1.0 },
        ],
        12,
    )?;
    // Average the held-out repeats per m.
    let mut heldout: Vec<Observation> = Vec::new();
    for &m in &[32usize, 64, 128] {
        let ts: Vec<f64> = truth
            .iter()
            .filter(|o| o.machines == m)
            .map(|o| o.time)
            .collect();
        heldout.push(Observation {
            machines: m,
            size: ctx.problem.data.n as f64,
            time: stats::mean(&ts),
        });
    }

    let nnls_model = ErnestModel::fit(&obs)?;
    let nnls_mape = nnls_model.mape(&heldout);

    // OLS variant (no nonnegativity).
    let a = Matrix::from_fn(obs.len(), 4, |i, j| {
        ErnestModel::features(obs[i].machines, obs[i].size)[j]
    });
    let b: Vec<f64> = obs.iter().map(|o| o.time).collect();
    let theta = lstsq(&a, &b)?;
    let ols_pred = |m: usize, size: f64| -> f64 {
        ErnestModel::features(m, size)
            .iter()
            .zip(&theta)
            .map(|(x, t)| x * t)
            .sum()
    };
    let truth_v: Vec<f64> = heldout.iter().map(|o| o.time).collect();
    let pred_v: Vec<f64> = heldout
        .iter()
        .map(|o| ols_pred(o.machines, o.size))
        .collect();
    let ols_mape = stats::mape(&truth_v, &pred_v);
    Ok((nnls_mape, ols_mape))
}

/// LOO-m score (mean |Δ log subopt| on held-out m) for a given
/// estimator over the shared sweep.
fn loo_score(
    fit: &SweepFit,
    held_out: usize,
    estimator: impl Fn(&[ConvPoint]) -> crate::Result<Box<dyn Fn(f64, f64) -> f64>>,
) -> crate::Result<f64> {
    let train: Vec<_> = fit
        .traces
        .traces
        .iter()
        .filter(|t| t.machines != held_out)
        .cloned()
        .collect();
    let test = fit
        .traces
        .find("cocoa+", held_out)
        .ok_or_else(|| crate::err!("no m={held_out} trace"))?;
    let predict = estimator(&points_from_traces(&train))?;
    let mut errs = Vec::new();
    for r in &test.records {
        if r.iter >= 1 && r.subopt > 0.0 {
            let p = predict(r.iter as f64, held_out as f64);
            errs.push((r.subopt.ln() - p).abs());
        }
    }
    Ok(stats::mean(&errs))
}

fn lasso_estimator(
    lib: FeatureLibrary,
) -> impl Fn(&[ConvPoint]) -> crate::Result<Box<dyn Fn(f64, f64) -> f64>> {
    move |pts| {
        let model = ConvergenceModel::fit(pts, lib.clone(), 1)?;
        Ok(Box::new(move |i, m| model.predict_ln(i, m)) as Box<dyn Fn(f64, f64) -> f64>)
    }
}

fn ols_estimator(
    lib: FeatureLibrary,
) -> impl Fn(&[ConvPoint]) -> crate::Result<Box<dyn Fn(f64, f64) -> f64>> {
    move |pts| {
        let x = Matrix::from_fn(pts.len(), lib.len() + 1, |i, j| {
            if j == 0 {
                1.0
            } else {
                lib.row(pts[i].iter, pts[i].machines)[j - 1]
            }
        });
        let y: Vec<f64> = pts.iter().map(|p| p.subopt.ln()).collect();
        let coef = lstsq(&x, &y)?;
        let lib = lib.clone();
        Ok(Box::new(move |i, m| {
            let row = lib.row(i, m);
            coef[0] + row.iter().zip(&coef[1..]).map(|(x, c)| x * c).sum::<f64>()
        }) as Box<dyn Fn(f64, f64) -> f64>)
    }
}

fn library_without_theory_terms() -> FeatureLibrary {
    let full = FeatureLibrary::standard();
    FeatureLibrary {
        features: full
            .features
            .into_iter()
            .filter(|f| !matches!(f.name, "i/m" | "i/m^2" | "i/sqrt(m)" | "sqrt(i)/m"))
            .collect::<Vec<Feature>>(),
    }
}

pub fn ablation(ctx: &ReproContext, fit: &SweepFit) -> crate::Result<String> {
    println!("== Ablations (DESIGN.md §7 design choices) ==");
    let mut table = Table::new(&["ablation_id", "variant_id", "score"]);

    // A1: Ernest solver (profiling inside fans out through the engine).
    let (nnls_mape, ols_mape) = ablate_ernest(ctx)?;
    println!("  A1 Ernest solver, extrapolation MAPE (m>16): NNLS {nnls_mape:.1}% vs OLS {ols_mape:.1}%");
    table.push(vec![1.0, 0.0, nnls_mape]);
    table.push(vec![1.0, 1.0, ols_mape]);

    // A2/A3: three independent LOO-m=128 estimator fits — run them
    // concurrently through the sweep engine's thread pool.
    let scores = ctx.sweep.try_map(3, |i| match i {
        0 => loo_score(fit, 128, lasso_estimator(FeatureLibrary::standard())),
        1 => loo_score(fit, 128, ols_estimator(FeatureLibrary::standard())),
        _ => loo_score(fit, 128, lasso_estimator(library_without_theory_terms())),
    })?;
    let (lasso128, ols128, no_theory) = (scores[0], scores[1], scores[2]);
    println!("  A2 g-estimator, LOO-m=128 mean |Δln|: LassoCV {lasso128:.3} vs OLS {ols128:.3}");
    table.push(vec![2.0, 0.0, lasso128]);
    table.push(vec![2.0, 1.0, ols128]);

    // A3: feature library with vs without the theory family.
    println!(
        "  A3 features, LOO-m=128 mean |Δln|: full library {lasso128:.3} vs no-(i/m family) {no_theory:.3}"
    );
    table.push(vec![3.0, 0.0, lasso128]);
    table.push(vec![3.0, 1.0, no_theory]);

    ctx.write_csv("ablation.csv", &table)?;
    let summary = format!(
        "ablation: A1 Ernest NNLS {nnls_mape:.1}% vs OLS {ols_mape:.1}% | A2 LassoCV {lasso128:.3} vs OLS {ols128:.3} | A3 full {lasso128:.3} vs no-theory {no_theory:.3} (LOO-m=128 |Δln|)"
    );
    println!("{summary}\n");
    Ok(summary)
}

//! Shared machinery for the per-figure reproduction targets.

use std::path::PathBuf;

use crate::cluster::{BspSim, HardwareProfile};
use crate::config::ExperimentConfig;
use crate::data::synth::mnist_like;
use crate::ernest::{ErnestModel, Observation};
use crate::optim::{
    by_name, run, Backend, HloBackend, NativeBackend, Problem, RunConfig, Trace, TraceSet,
};
use crate::runtime::Engine;
use crate::util::asciiplot::{plot, PlotCfg, Series};

/// Everything a figure target needs.
pub struct ReproContext {
    pub cfg: ExperimentConfig,
    pub problem: Problem,
    pub p_star: f64,
    pub profile: HardwareProfile,
    engine: Option<Engine>,
    pub use_native: bool,
    pub out_dir: PathBuf,
}

impl ReproContext {
    /// Build the context: dataset, reference optimum, backend.
    ///
    /// `use_native` switches per-partition compute to the native
    /// mirror (used by fast CI paths); the default is the production
    /// HLO/PJRT path.
    pub fn new(cfg: ExperimentConfig, use_native: bool) -> crate::Result<ReproContext> {
        let data = mnist_like(&cfg.synth());
        let problem = Problem::new(data, cfg.lambda);
        crate::log_info!(
            "dataset ready: n={} d={} positives={:.1}%",
            problem.data.n,
            problem.data.d,
            100.0 * problem.data.positive_rate()
        );
        let t0 = std::time::Instant::now();
        let (p_star, _, gap) = problem.reference_solve(1e-7, 600);
        crate::log_info!(
            "reference solve: P*={p_star:.6} (gap {gap:.2e}, {:.2}s)",
            t0.elapsed().as_secs_f64()
        );
        let engine = if use_native {
            None
        } else {
            Some(Engine::new(&crate::runtime::default_artifact_dir())?)
        };
        let profile = HardwareProfile::by_name(&cfg.profile)?;
        let out_dir = PathBuf::from(&cfg.out_dir);
        std::fs::create_dir_all(&out_dir)?;
        Ok(ReproContext {
            problem,
            p_star,
            profile,
            engine,
            use_native,
            out_dir,
            cfg,
        })
    }

    /// The active backend.
    pub fn backend(&self) -> Box<dyn Backend + '_> {
        match &self.engine {
            Some(e) => Box::new(HloBackend::new(e)),
            None => Box::new(NativeBackend),
        }
    }

    /// Run one (algorithm, m) to the paper's stopping rule on a fresh
    /// simulated cluster.
    pub fn run_one(&self, algo_name: &str, machines: usize) -> crate::Result<Trace> {
        let mut algo = by_name(algo_name, &self.problem, machines, self.cfg.seed as u32)?;
        let mut sim = BspSim::new(self.profile.clone(), self.cfg.seed ^ machines as u64);
        let backend = self.backend();
        let run_cfg = RunConfig {
            max_iters: self.cfg.max_iters,
            target_subopt: self.cfg.target_subopt,
            time_budget: None,
        };
        let t0 = std::time::Instant::now();
        let trace = run(
            algo.as_mut(),
            backend.as_ref(),
            &self.problem,
            &mut sim,
            self.p_star,
            &run_cfg,
        )?;
        crate::log_info!(
            "{algo_name} m={machines}: {} iters, final subopt {:.2e} ({:.1}s wall)",
            trace.records.last().map(|r| r.iter).unwrap_or(0),
            trace.final_subopt(),
            t0.elapsed().as_secs_f64()
        );
        Ok(trace)
    }

    /// Run a machine sweep for one algorithm.
    pub fn run_sweep(&self, algo_name: &str) -> crate::Result<TraceSet> {
        let mut set = TraceSet::default();
        for &m in &self.cfg.machines {
            set.push(self.run_one(algo_name, m)?);
        }
        Ok(set)
    }

    /// Ernest-style profiling: run a few iterations at each selected
    /// (machines, data-fraction) config, recording per-iteration times.
    pub fn profile_system(
        &self,
        algo_name: &str,
        configs: &[crate::ernest::design::Candidate],
        iters_per_config: usize,
    ) -> crate::Result<Vec<Observation>> {
        let backend = self.backend();
        let mut obs = Vec::new();
        for c in configs {
            let rows = ((self.problem.data.n as f64) * c.fraction) as usize;
            let sub = self.problem.data.subsample(rows, self.cfg.seed ^ 0xE51);
            let sub_problem = Problem::new(sub, self.cfg.lambda);
            let mut algo = by_name(algo_name, &sub_problem, c.machines, self.cfg.seed as u32)?;
            let mut sim = BspSim::new(self.profile.clone(), self.cfg.seed ^ (rows as u64) << 8);
            for i in 0..iters_per_config {
                let cost = algo.step(backend.as_ref(), i)?;
                let dt = sim.iteration_time(&cost);
                obs.push(Observation {
                    machines: c.machines,
                    size: rows as f64,
                    time: dt,
                });
            }
        }
        Ok(obs)
    }

    /// Fit the Ernest model from a default profiling pass.
    ///
    /// Candidates go up to m=16 (12.5% of the 128-machine target —
    /// Ernest's "small configs" regime) with 8 timed iterations per
    /// config so per-iteration noise averages out.
    pub fn fit_ernest(&self, algo_name: &str) -> crate::Result<ErnestModel> {
        let candidates = crate::ernest::design::default_candidates(16);
        let selected = crate::ernest::design::select_configs(
            &candidates,
            self.problem.data.n as f64,
            10,
        );
        let obs = self.profile_system(algo_name, &selected, 8)?;
        let model = ErnestModel::fit(&obs)?;
        crate::log_info!(
            "Ernest fit: θ = [{:.4}, {:.3e}, {:.4}, {:.5}] (train rmse {:.4})",
            model.theta[0],
            model.theta[1],
            model.theta[2],
            model.theta[3],
            model.train_rmse
        );
        Ok(model)
    }

    /// Write a CSV and echo its path.
    pub fn write_csv(&self, name: &str, table: &crate::util::csv::Table) -> crate::Result<()> {
        let path = self.out_dir.join(name);
        table.write(&path)?;
        println!("  wrote {}", path.display());
        Ok(())
    }

    /// Print an ASCII chart.
    pub fn show(&self, title: &str, series: Vec<Series>, log_y: bool, x_label: &str) {
        let cfg = PlotCfg {
            title: title.into(),
            log_y,
            x_label: x_label.into(),
            ..Default::default()
        };
        println!("{}", plot(&series, &cfg));
    }
}

/// Convert a trace into (iteration, suboptimality) points.
pub fn iter_series(trace: &Trace, cap: Option<usize>) -> Vec<(f64, f64)> {
    trace
        .records
        .iter()
        .filter(|r| r.iter >= 1 && r.subopt > 0.0)
        .filter(|r| cap.map(|c| r.iter <= c).unwrap_or(true))
        .map(|r| (r.iter as f64, r.subopt))
        .collect()
}

/// Convert a trace into (sim_time, suboptimality) points.
pub fn time_series(trace: &Trace, cap: Option<f64>) -> Vec<(f64, f64)> {
    trace
        .records
        .iter()
        .filter(|r| r.iter >= 1 && r.subopt > 0.0)
        .filter(|r| cap.map(|c| r.sim_time <= c).unwrap_or(true))
        .map(|r| (r.sim_time, r.subopt))
        .collect()
}

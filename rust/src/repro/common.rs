//! Shared machinery for the per-figure reproduction targets.
//!
//! All trace-producing grids go through the [`SweepEngine`]: cells fan
//! out across the thread pool (native backend) or run serially (PJRT,
//! whose client is not shared across threads), and finished traces are
//! cached in memory and on disk under `<out_dir>/cache/` so repeated
//! figure runs and advisor refits skip already-converged cells.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::advisor::{
    artifact_path, save_artifact, AlgorithmId, CombinedModel, ModeModel, ModelKey, ModelRegistry,
};
use crate::cluster::{BarrierMode, ClusterSim, FleetSpec, HardwareProfile, Scenario};
use crate::config::ExperimentConfig;
use crate::data::synth::{dataset_for, dataset_for_scenario};
use crate::data::DataScenario;
use crate::ernest::{ErnestModel, Observation};
use crate::hemingway_model::{points_from_traces, ConvPoint, ConvergenceModel, FeatureLibrary};
use crate::optim::{
    by_name, run, Backend, HloBackend, NativeBackend, Objective, Problem, RunConfig, Trace,
    TraceSet,
};
use crate::runtime::Engine;
use crate::sweep::{CellSpec, SweepEngine, SweepGrid, TraceCache};
use crate::util::asciiplot::{plot, PlotCfg, Series};

/// One workload's problem plus its certified reference optimum — the
/// pair every sweep cell of that workload shares.
pub struct WorkloadProblem {
    pub problem: Problem,
    pub p_star: f64,
}

/// Everything a figure target needs.
pub struct ReproContext {
    pub cfg: ExperimentConfig,
    /// The base workload's problem (the config's first `workloads`
    /// entry; hinge for legacy configs — bit-identical construction).
    pub problem: Problem,
    pub p_star: f64,
    pub profile: HardwareProfile,
    engine: Option<Engine>,
    pub use_native: bool,
    pub out_dir: PathBuf,
    /// The shared sweep executor + trace cache.
    pub sweep: SweepEngine,
    /// Config-hash prefix pinning dataset, problem, profile and backend
    /// for every cell this context runs.
    pub context_key: String,
    /// Lazily built per-workload problems + reference optima (the base
    /// workload is seeded at construction; others are built — dataset
    /// generation plus a reference solve — on first use and shared
    /// across grids from then on).
    workload_problems: Mutex<Vec<(Objective, Arc<WorkloadProblem>)>>,
    /// Same lazy cache for non-dense data scenarios, keyed by
    /// (workload, canonical scenario string). Dense/implicit cells
    /// route through `workload_problems` instead — one shared problem.
    scenario_problems: Mutex<Vec<((Objective, String), Arc<WorkloadProblem>)>>,
}

impl ReproContext {
    /// Build the context: dataset, reference optimum, backend.
    ///
    /// `use_native` switches per-partition compute to the native
    /// mirror (used by fast CI paths); the default is the production
    /// HLO/PJRT path.
    pub fn new(cfg: ExperimentConfig, use_native: bool) -> crate::Result<ReproContext> {
        let engine = if use_native {
            None
        } else {
            Some(Engine::new(&crate::runtime::default_artifact_dir())?)
        };
        Self::build(cfg, engine)
    }

    /// Prefer the PJRT path, fall back to the native backend when the
    /// engine is unavailable (no `pjrt` feature / no artifacts) — the
    /// entry point the examples use. The probed engine is reused, so
    /// neither the engine nor the expensive dataset + reference solve
    /// is constructed twice.
    pub fn new_with_fallback(cfg: ExperimentConfig) -> crate::Result<ReproContext> {
        let engine = match Engine::new(&crate::runtime::default_artifact_dir()) {
            Ok(engine) => Some(engine),
            Err(e) => {
                crate::log_warn!("PJRT path unavailable ({e}); falling back to the native backend");
                None
            }
        };
        Self::build(cfg, engine)
    }

    fn build(cfg: ExperimentConfig, engine: Option<Engine>) -> crate::Result<ReproContext> {
        let use_native = engine.is_none();
        let base_workload = cfg.base_workload();
        let data = dataset_for(base_workload, &cfg.synth());
        let problem = Problem::with_objective(data, cfg.lambda, base_workload);
        crate::log_info!(
            "dataset ready: workload={} n={} d={} positives={:.1}%",
            base_workload,
            problem.data.n,
            problem.data.d,
            100.0 * problem.data.positive_rate()
        );
        let t0 = std::time::Instant::now();
        let (p_star, _, gap) = problem.reference_solve(1e-7, 600);
        crate::log_info!(
            "reference solve: P*={p_star:.6} (gap {gap:.2e}, {:.2}s)",
            t0.elapsed().as_secs_f64()
        );
        let profile = HardwareProfile::by_name(&cfg.profile)?;
        let out_dir = PathBuf::from(&cfg.out_dir);
        std::fs::create_dir_all(&out_dir)?;
        let context_key = cfg.context_key(use_native);
        let sweep = SweepEngine::with_default_threads(TraceCache::persistent(&out_dir.join("cache")));
        let workload_problems = Mutex::new(vec![(
            base_workload,
            Arc::new(WorkloadProblem {
                problem: problem.clone(),
                p_star,
            }),
        )]);
        Ok(ReproContext {
            problem,
            p_star,
            profile,
            engine,
            use_native,
            out_dir,
            sweep,
            context_key,
            workload_problems,
            scenario_problems: Mutex::new(Vec::new()),
            cfg,
        })
    }

    /// The base workload (the config's first `workloads` entry).
    pub fn base_workload(&self) -> Objective {
        self.cfg.base_workload()
    }

    /// The (problem, P*) pair a workload's cells run against. The base
    /// workload is seeded at construction; any other workload is built
    /// on first use (dataset generation + high-precision reference
    /// solve) and cached for every later grid.
    pub fn workload_problem(&self, workload: Objective) -> crate::Result<Arc<WorkloadProblem>> {
        let mut cache = self.workload_problems.lock().unwrap();
        if let Some((_, wp)) = cache.iter().find(|(w, _)| *w == workload) {
            return Ok(wp.clone());
        }
        let data = dataset_for(workload, &self.cfg.synth());
        let problem = Problem::with_objective(data, self.cfg.lambda, workload);
        let t0 = std::time::Instant::now();
        let (p_star, _, gap) = problem.reference_solve(1e-7, 600);
        crate::log_info!(
            "workload {workload} ready: P*={p_star:.6} (gap {gap:.2e}, {:.2}s)",
            t0.elapsed().as_secs_f64()
        );
        let wp = Arc::new(WorkloadProblem { problem, p_star });
        cache.push((workload, wp.clone()));
        Ok(wp)
    }

    /// The (problem, P*) pair a (workload, data scenario) cell runs
    /// against. The implicit (`""`) and explicit `dense` scenarios
    /// route through [`Self::workload_problem`] — one shared,
    /// bit-identical historical problem; every other scenario is built
    /// on first use (scenario generation + reference solve) and cached
    /// for every later grid.
    pub fn scenario_problem(
        &self,
        workload: Objective,
        data: &str,
    ) -> crate::Result<Arc<WorkloadProblem>> {
        if data.is_empty() {
            return self.workload_problem(workload);
        }
        let scenario = DataScenario::parse(data)?;
        if scenario.is_dense() {
            return self.workload_problem(workload);
        }
        let mut cache = self.scenario_problems.lock().unwrap();
        if let Some((_, wp)) = cache
            .iter()
            .find(|((w, d), _)| *w == workload && d.as_str() == data)
        {
            return Ok(wp.clone());
        }
        let matrix = dataset_for_scenario(workload, &scenario, &self.cfg.synth());
        let problem = Problem::with_objective(matrix, self.cfg.lambda, workload);
        let t0 = std::time::Instant::now();
        let (p_star, _, gap) = problem.reference_solve(1e-7, 600);
        crate::log_info!(
            "workload {workload} data {data} ready: P*={p_star:.6} (gap {gap:.2e}, {:.2}s)",
            t0.elapsed().as_secs_f64()
        );
        let wp = Arc::new(WorkloadProblem { problem, p_star });
        cache.push(((workload, data.to_string()), wp.clone()));
        Ok(wp)
    }

    /// The active backend.
    pub fn backend(&self) -> Box<dyn Backend + '_> {
        match &self.engine {
            Some(e) => Box::new(HloBackend::new(e)),
            None => Box::new(NativeBackend),
        }
    }

    /// The paper's stopping rules from the config.
    pub fn run_config(&self) -> RunConfig {
        RunConfig {
            max_iters: self.cfg.max_iters,
            target_subopt: self.cfg.target_subopt,
            time_budget: None,
        }
    }

    /// The base fleet's wire name: the config's first `fleets` entry,
    /// or the empty string (= the uniform fleet of `cfg.profile` under
    /// the pre-fleet cache-key shape).
    pub fn base_fleet_name(&self) -> String {
        self.cfg.fleets.first().cloned().unwrap_or_default()
    }

    /// Fleet axis for single-fleet grids: the base fleet alone, in the
    /// shape `SweepGrid.fleets` expects (empty = unnamed default).
    pub fn base_fleet_axis(&self) -> Vec<String> {
        match self.cfg.fleets.first() {
            Some(f) => vec![f.clone()],
            None => Vec::new(),
        }
    }

    /// Data axis for single-scenario grids: the base scenario alone,
    /// in the shape `SweepGrid.data` expects (empty = the implicit
    /// dense scenario of the pre-data-axis cache-key shape).
    pub fn base_data_axis(&self) -> Vec<String> {
        match self.cfg.data_scenarios.first() {
            Some(d) => vec![d.clone()],
            None => Vec::new(),
        }
    }

    /// Resolve a cell's fleet wire name against this context ("" = the
    /// uniform fleet of the config's profile).
    pub fn fleet_for(&self, name: &str) -> crate::Result<FleetSpec> {
        if name.is_empty() {
            Ok(FleetSpec::uniform(self.profile.clone()))
        } else {
            FleetSpec::parse(name)
        }
    }

    /// The config-hash prefix every cell of `grid` is keyed under —
    /// what [`SweepEngine::plan`] needs to report resume progress for
    /// this context.
    pub fn grid_context_key(&self, grid: &SweepGrid) -> String {
        format!("{}|{}", self.context_key, grid.run_key())
    }

    /// Run a full grid through the sweep engine, consulting the trace
    /// cache per cell. Parallel across cells on the native backend;
    /// serial (but still cached) on PJRT. Results come back in
    /// [`SweepGrid::cells`] order regardless of thread count.
    ///
    /// This collects every trace; grids too large to hold resident
    /// should go through [`Self::run_grid_stream`].
    pub fn run_grid(&self, grid: &SweepGrid) -> crate::Result<Vec<Trace>> {
        let mut out = Vec::new();
        self.run_grid_stream(grid, &mut |_, t| {
            out.push(t);
            Ok(())
        })?;
        Ok(out)
    }

    /// Streaming variant of [`Self::run_grid`]: each finished trace is
    /// handed to `sink(cell_index, trace)` in [`SweepGrid::cells`]
    /// order and then dropped, so peak resident traces are O(threads)
    /// regardless of grid size.
    pub fn run_grid_stream(
        &self,
        grid: &SweepGrid,
        sink: &mut dyn FnMut(usize, Trace) -> crate::Result<()>,
    ) -> crate::Result<()> {
        let context_key = self.grid_context_key(grid);
        let cells = grid.cells();
        // Resolve every distinct fleet and workload once, before the
        // fan-out: a malformed spec (or an expensive reference solve)
        // is paid up front, and workers share read-only parsed specs
        // and problems instead of rebuilding them per cell.
        let mut fleets: Vec<(String, FleetSpec)> = Vec::new();
        let mut problems: Vec<((Objective, String), Arc<WorkloadProblem>)> = Vec::new();
        for cell in &cells {
            // The HLO backend's artifacts are hinge-only; fail before
            // the expensive per-workload reference solves, not on the
            // first cell mid-sweep.
            crate::ensure!(
                self.use_native || cell.workload.is_hinge(),
                "workload '{}' requires the native backend (--native); \
                 the HLO artifacts are compiled for hinge",
                cell.workload
            );
            // Likewise any non-dense scenario: the artifacts are
            // compiled for the dense store and uniform partitions.
            crate::ensure!(
                self.use_native || cell.data.is_empty() || cell.data == "dense",
                "data scenario '{}' requires the native backend (--native); \
                 the HLO artifacts are compiled for the dense IID store",
                cell.data
            );
            if !fleets.iter().any(|(name, _)| *name == cell.fleet) {
                fleets.push((cell.fleet.clone(), self.fleet_for(&cell.fleet)?));
            }
            if !problems
                .iter()
                .any(|((w, d), _)| *w == cell.workload && *d == cell.data)
            {
                problems.push((
                    (cell.workload, cell.data.clone()),
                    self.scenario_problem(cell.workload, &cell.data)?,
                ));
            }
        }
        if self.use_native {
            let run_cfg = grid.run.clone();
            let fleets = &fleets;
            let problems = &problems;
            self.sweep.run_cells_stream(
                &context_key,
                &cells,
                &|cell, _scratch| run_cell(&NativeBackend, problems, fleets, cell, &run_cfg),
                sink,
            )
        } else {
            let backend = self.backend();
            self.sweep.run_cells_serial_stream(
                &context_key,
                &cells,
                &mut |cell, _scratch| {
                    run_cell(backend.as_ref(), &problems, &fleets, cell, &grid.run)
                },
                sink,
            )
        }
    }

    /// Run one (algorithm, m) to the paper's stopping rule on a fresh
    /// simulated cluster (through the engine, so repeats are cached).
    pub fn run_one(&self, algo_name: &str, machines: usize) -> crate::Result<Trace> {
        let mut grid =
            SweepGrid::single(algo_name, &[machines], self.cfg.seed, self.run_config());
        grid.fleets = self.base_fleet_axis();
        grid.workloads = vec![self.base_workload()];
        grid.data = self.base_data_axis();
        let traces = self.run_grid(&grid)?;
        Ok(traces.into_iter().next().expect("single-cell grid"))
    }

    /// Traces for one algorithm across a machine list, with custom
    /// stopping rules.
    pub fn run_traces(
        &self,
        algo_name: &str,
        machines: &[usize],
        run: RunConfig,
    ) -> crate::Result<Vec<Trace>> {
        let mut grid = SweepGrid::single(algo_name, machines, self.cfg.seed, run);
        grid.fleets = self.base_fleet_axis();
        grid.workloads = vec![self.base_workload()];
        grid.data = self.base_data_axis();
        self.run_grid(&grid)
    }

    /// Traces for several algorithms at one machine count.
    pub fn run_algos(&self, algos: &[&str], machines: usize) -> crate::Result<Vec<Trace>> {
        self.run_grid(&SweepGrid {
            algorithms: algos.iter().map(|s| s.to_string()).collect(),
            machines: vec![machines],
            modes: vec![BarrierMode::Bsp],
            fleets: self.base_fleet_axis(),
            workloads: vec![self.base_workload()],
            data: self.base_data_axis(),
            events: String::new(),
            seeds: 1,
            base_seed: self.cfg.seed,
            run: self.run_config(),
        })
    }

    /// Run a machine sweep for one algorithm (BSP, base fleet).
    pub fn run_sweep(&self, algo_name: &str) -> crate::Result<TraceSet> {
        self.run_sweep_in_mode(algo_name, BarrierMode::Bsp)
    }

    /// Run a machine sweep for one algorithm under one barrier mode on
    /// the base fleet.
    pub fn run_sweep_in_mode(
        &self,
        algo_name: &str,
        mode: BarrierMode,
    ) -> crate::Result<TraceSet> {
        self.run_sweep_variant(algo_name, mode, &self.base_fleet_name())
    }

    /// Run a machine sweep for one algorithm under one (mode, fleet)
    /// variant on the base workload — the advisor's per-variant fit
    /// input.
    pub fn run_sweep_variant(
        &self,
        algo_name: &str,
        mode: BarrierMode,
        fleet: &str,
    ) -> crate::Result<TraceSet> {
        self.run_sweep_workload(algo_name, self.base_workload(), mode, fleet)
    }

    /// Run a machine sweep for one algorithm under one (workload,
    /// mode, fleet) variant — the fully-qualified fit input the
    /// workload axis adds.
    pub fn run_sweep_workload(
        &self,
        algo_name: &str,
        workload: Objective,
        mode: BarrierMode,
        fleet: &str,
    ) -> crate::Result<TraceSet> {
        let mut grid = SweepGrid::single_in_mode(
            algo_name,
            &self.cfg.machines,
            mode,
            self.cfg.seed,
            self.run_config(),
        );
        if !fleet.is_empty() {
            grid.fleets = vec![fleet.to_string()];
        }
        grid.workloads = vec![workload];
        let traces = self.run_grid(&grid)?;
        let mut set = TraceSet::default();
        for t in traces {
            set.push(t);
        }
        Ok(set)
    }

    /// Ernest-style profiling: run a few iterations at each selected
    /// (machines, data-fraction) config, recording per-iteration times.
    /// Configs fan out across the thread pool on the native backend;
    /// each task owns its subsampled problem and simulator, and seeds
    /// depend only on the config, so results are order-independent.
    pub fn profile_system(
        &self,
        algo_name: &str,
        configs: &[crate::ernest::design::Candidate],
        iters_per_config: usize,
    ) -> crate::Result<Vec<Observation>> {
        // Profiling runs on the base fleet (the uniform profile when
        // the config names no fleets — bit-identical to the historical
        // plain-profile path) and the base data scenario (ditto: the
        // implicit dense scenario shares `self.problem`).
        let fleet = self.fleet_for(&self.base_fleet_name())?;
        let base = self.scenario_problem(self.base_workload(), self.cfg.base_data())?;
        let per_config: Vec<Vec<Observation>> = if self.use_native {
            let problem = &base.problem;
            let fleet = &fleet;
            let seed = self.cfg.seed;
            let lambda = self.cfg.lambda;
            self.sweep.try_map(configs.len(), |i| {
                profile_one(
                    &NativeBackend,
                    problem,
                    fleet,
                    seed,
                    lambda,
                    algo_name,
                    &configs[i],
                    iters_per_config,
                )
            })?
        } else {
            let backend = self.backend();
            let mut out = Vec::with_capacity(configs.len());
            for c in configs {
                out.push(profile_one(
                    backend.as_ref(),
                    &base.problem,
                    &fleet,
                    self.cfg.seed,
                    self.cfg.lambda,
                    algo_name,
                    c,
                    iters_per_config,
                )?);
            }
            out
        };
        Ok(per_config.into_iter().flatten().collect())
    }

    /// Fit the Ernest model from a default profiling pass.
    ///
    /// Candidates go up to m=16 (12.5% of the 128-machine target —
    /// Ernest's "small configs" regime) with 8 timed iterations per
    /// config so per-iteration noise averages out.
    pub fn fit_ernest(&self, algo_name: &str) -> crate::Result<ErnestModel> {
        let candidates = crate::ernest::design::default_candidates(16);
        let selected = crate::ernest::design::select_configs(
            &candidates,
            self.problem.data.n as f64,
            10,
        );
        let obs = self.profile_system(algo_name, &selected, 8)?;
        let model = ErnestModel::fit(&obs)?;
        crate::log_info!(
            "Ernest fit: θ = [{:.4}, {:.3e}, {:.4}, {:.5}] (train rmse {:.4})",
            model.theta[0],
            model.theta[1],
            model.theta[2],
            model.theta[3],
            model.train_rmse
        );
        Ok(model)
    }

    /// Fit the full combined model for one algorithm: convergence
    /// model from the machine sweep, system model from Ernest-style
    /// profiling. Every non-BSP mode in the config's `barrier_modes`
    /// gets its own (f, g) pair fitted from a sweep simulated under
    /// that mode, and every fleet beyond the base one gets a pair per
    /// mode (BSP included) fitted from sweeps priced on that hardware
    /// — the sweeps also supply each variant's iteration-time
    /// observations, since relaxed barriers and slower fleets both
    /// change f. This is the expensive half of the fit-once /
    /// query-many split — `hemingway fit` persists the result so
    /// `advise` and `serve` never pay it again.
    pub fn fit_combined(&self, algo: AlgorithmId) -> crate::Result<CombinedModel> {
        let base_fleet = self.base_fleet_name();
        let base_workload = self.base_workload();
        let (pts, _) =
            self.sweep_fit_inputs(algo.as_str(), base_workload, BarrierMode::Bsp, &base_fleet)?;
        let conv = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), self.cfg.seed)?;
        let ernest = self.fit_ernest(algo.as_str())?;
        let mut model = CombinedModel::new(ernest, conv, self.problem.data.n as f64);
        model.base_fleet = base_fleet.clone();
        model.base_workload = base_workload;
        model.base_data = self.cfg.base_data().to_string();
        for &mode in &self.cfg.barrier_modes {
            if mode.is_bsp() {
                continue;
            }
            let pair = self.fit_variant_pair(algo, base_workload, mode, &base_fleet)?;
            model.insert_mode(mode, pair);
        }
        let mut modes = vec![BarrierMode::Bsp];
        for &mode in &self.cfg.barrier_modes {
            if !mode.is_bsp() && !modes.contains(&mode) {
                modes.push(mode);
            }
        }
        for fleet in self.cfg.fleets.iter().skip(1) {
            for &mode in &modes {
                let pair = self.fit_variant_pair(algo, base_workload, mode, fleet)?;
                model.insert_fleet_pair(fleet, mode, pair);
            }
        }
        // Every non-base workload gets its own per-mode pairs on the
        // base fleet (the workload axis changes g — and f, via
        // per-iteration flops — so nothing is shared with the base
        // pairs; crossing workloads with non-base fleets is left to an
        // explicit future need, keeping fit cost linear in the axes).
        for &workload in &self.cfg.workloads {
            if workload == base_workload {
                continue;
            }
            for &mode in &modes {
                let pair = self.fit_variant_pair(algo, workload, mode, &base_fleet)?;
                model.insert_workload_pair(workload, &base_fleet, mode, pair);
            }
        }
        // And every non-base data scenario gets per-mode pairs on the
        // base fleet and base workload — a scenario changes g (sparse
        // rounds make different per-round progress) *and* f (per-row
        // flops, skewed per-machine loads). Crossing scenarios with
        // non-base fleets or workloads is left to an explicit future
        // need, keeping fit cost linear in the axes.
        let base_data = self.cfg.base_data().to_string();
        for data in &self.cfg.data_scenarios {
            if *data == base_data {
                continue;
            }
            for &mode in &modes {
                let pair =
                    self.fit_scenario_pair(algo, base_workload, mode, &base_fleet, data)?;
                model.insert_data_pair(data, base_workload, &base_fleet, mode, pair);
            }
        }
        Ok(model)
    }

    /// The two fit inputs (convergence points, per-iteration timing
    /// observations) for one (algorithm, workload, mode, fleet) sweep,
    /// computed by streaming: each trace is reduced to its points and
    /// observations as it finishes, then dropped — the fit path never
    /// holds a sweep's traces resident. Point and observation order is
    /// identical to the collect-then-convert path (both conversions
    /// are per-trace folds in cell order).
    pub fn sweep_fit_inputs(
        &self,
        algo_name: &str,
        workload: Objective,
        mode: BarrierMode,
        fleet: &str,
    ) -> crate::Result<(Vec<ConvPoint>, Vec<Observation>)> {
        self.sweep_fit_inputs_data(algo_name, workload, mode, fleet, self.cfg.base_data())
    }

    /// [`Self::sweep_fit_inputs`] under an explicit data scenario
    /// (empty = the implicit dense dataset).
    pub fn sweep_fit_inputs_data(
        &self,
        algo_name: &str,
        workload: Objective,
        mode: BarrierMode,
        fleet: &str,
        data: &str,
    ) -> crate::Result<(Vec<ConvPoint>, Vec<Observation>)> {
        let mut grid = SweepGrid::single_in_mode(
            algo_name,
            &self.cfg.machines,
            mode,
            self.cfg.seed,
            self.run_config(),
        );
        if !fleet.is_empty() {
            grid.fleets = vec![fleet.to_string()];
        }
        grid.workloads = vec![workload];
        if !data.is_empty() {
            grid.data = vec![data.to_string()];
        }
        let size = self.problem.data.n as f64;
        let mut pts: Vec<ConvPoint> = Vec::new();
        let mut obs: Vec<Observation> = Vec::new();
        self.run_grid_stream(&grid, &mut |_, t| {
            let one = std::slice::from_ref(&t);
            pts.extend(points_from_traces(one));
            obs.extend(observations_from_traces(one, size));
            Ok(())
        })?;
        Ok((pts, obs))
    }

    /// Fit one (workload, mode, fleet) pair from a sweep run under
    /// that variant.
    fn fit_variant_pair(
        &self,
        algo: AlgorithmId,
        workload: Objective,
        mode: BarrierMode,
        fleet: &str,
    ) -> crate::Result<ModeModel> {
        let (pts, obs) = self.sweep_fit_inputs(algo.as_str(), workload, mode, fleet)?;
        let conv = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), self.cfg.seed)?;
        let ernest = crate::ernest::ErnestModel::fit(&obs)?;
        crate::log_info!(
            "{algo} {mode} fleet={} workload={workload}: conv R²={:.4}, \
             f(θ)=[{:.4}, {:.3e}, {:.4}, {:.5}]",
            if fleet.is_empty() { "-" } else { fleet },
            conv.train_r2,
            ernest.theta[0],
            ernest.theta[1],
            ernest.theta[2],
            ernest.theta[3]
        );
        Ok(ModeModel { ernest, conv })
    }

    /// Fit one non-base data scenario's (workload, mode, fleet) pair
    /// from a sweep run on that scenario's dataset.
    fn fit_scenario_pair(
        &self,
        algo: AlgorithmId,
        workload: Objective,
        mode: BarrierMode,
        fleet: &str,
        data: &str,
    ) -> crate::Result<ModeModel> {
        let (pts, obs) =
            self.sweep_fit_inputs_data(algo.as_str(), workload, mode, fleet, data)?;
        let conv = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), self.cfg.seed)?;
        let ernest = crate::ernest::ErnestModel::fit(&obs)?;
        crate::log_info!(
            "{algo} {mode} fleet={} workload={workload} data={data}: conv R²={:.4}, \
             f(θ)=[{:.4}, {:.3e}, {:.4}, {:.5}]",
            if fleet.is_empty() { "-" } else { fleet },
            conv.train_r2,
            ernest.theta[0],
            ernest.theta[1],
            ernest.theta[2],
            ernest.theta[3]
        );
        Ok(ModeModel { ernest, conv })
    }

    /// Write a CSV and echo its path.
    pub fn write_csv(&self, name: &str, table: &crate::util::csv::Table) -> crate::Result<()> {
        let path = self.out_dir.join(name);
        table.write(&path)?;
        println!("  wrote {}", path.display());
        Ok(())
    }

    /// Print an ASCII chart.
    pub fn show(&self, title: &str, series: Vec<Series>, log_y: bool, x_label: &str) {
        let cfg = PlotCfg {
            title: title.into(),
            log_y,
            x_label: x_label.into(),
            ..Default::default()
        };
        println!("{}", plot(&series, &cfg));
    }
}

/// Run one grid cell: fresh algorithm + simulator against the shared
/// read-only problem of the cell's workload. Seeds are pure functions
/// of the cell, so any worker may run any cell in any order. `fleets`
/// and `problems` map each cell's fleet wire name / workload to its
/// pre-resolved spec / problem (resolved once per grid).
fn run_cell(
    backend: &dyn Backend,
    problems: &[((Objective, String), Arc<WorkloadProblem>)],
    fleets: &[(String, FleetSpec)],
    cell: &CellSpec,
    run_cfg: &RunConfig,
) -> crate::Result<Trace> {
    let wp = problems
        .iter()
        .find(|((w, d), _)| *w == cell.workload && *d == cell.data)
        .map(|(_, wp)| wp.clone())
        .ok_or_else(|| {
            crate::err!(
                "cell (workload '{}', data '{}') was not pre-resolved",
                cell.workload,
                cell.data
            )
        })?;
    let problem = &wp.problem;
    let mut algo = by_name(&cell.algorithm, problem, cell.machines, cell.seed as u32)?;
    let fleet = fleets
        .iter()
        .find(|(name, _)| *name == cell.fleet)
        .map(|(_, spec)| spec.clone())
        .ok_or_else(|| crate::err!("cell fleet '{}' was not pre-resolved", cell.fleet))?;
    // Same seed across modes, fleets and workloads: one noise
    // realization, priced under every variant.
    let mut sim = ClusterSim::with_fleet(fleet, cell.mode, cell.seed ^ cell.machines as u64);
    if !cell.events.is_empty() {
        // An event-carrying cell replays its failure scenario; the
        // static path never parses (or pays for) one.
        sim = sim.with_scenario(&Scenario::parse(&cell.events)?);
    }
    let t0 = std::time::Instant::now();
    let mut trace = run(algo.as_mut(), backend, problem, &mut sim, wp.p_star, run_cfg)?;
    trace.fleet = cell.fleet.clone();
    trace.data = cell.data.clone();
    trace.events = cell.events.clone();
    crate::log_info!(
        "{} m={} mode={} fleet={} workload={} data={} rep={}: {} iters, final subopt {:.2e} ({:.1}s wall)",
        cell.algorithm,
        cell.machines,
        cell.mode,
        if cell.fleet.is_empty() { "-" } else { &cell.fleet },
        cell.workload,
        if cell.data.is_empty() { "-" } else { &cell.data },
        cell.replicate,
        trace.records.last().map(|r| r.iter).unwrap_or(0),
        trace.final_subopt(),
        t0.elapsed().as_secs_f64()
    );
    Ok(trace)
}

/// Per-iteration timing observations from finished traces — how the
/// non-BSP modes get their Ernest fits (their iteration time is a
/// property of the whole clock simulation, not of one barrier max, so
/// it is measured from the same sweeps that feed the convergence fit).
pub fn observations_from_traces(traces: &[Trace], size: f64) -> Vec<Observation> {
    let mut obs = Vec::new();
    for t in traces {
        for dt in t.iter_times() {
            if dt.is_finite() && dt > 0.0 {
                obs.push(Observation {
                    machines: t.machines,
                    size,
                    time: dt,
                });
            }
        }
    }
    obs
}

/// Profile one (machines, fraction) candidate on its own subsampled
/// problem and simulator.
#[allow(clippy::too_many_arguments)]
fn profile_one(
    backend: &dyn Backend,
    problem: &Problem,
    fleet: &FleetSpec,
    seed: u64,
    lambda: f64,
    algo_name: &str,
    c: &crate::ernest::design::Candidate,
    iters_per_config: usize,
) -> crate::Result<Vec<Observation>> {
    let rows = ((problem.data.n as f64) * c.fraction) as usize;
    let sub = problem.data.subsample(rows, seed ^ 0xE51)?;
    let sub_problem = Problem::with_objective(sub, lambda, problem.objective);
    let mut algo = by_name(algo_name, &sub_problem, c.machines, seed as u32)?;
    let mut sim =
        ClusterSim::with_fleet(fleet.clone(), BarrierMode::Bsp, seed ^ (rows as u64) << 8);
    let mut obs = Vec::with_capacity(iters_per_config);
    for i in 0..iters_per_config {
        let cost = algo.step(backend, i)?;
        let dt = sim.iteration_time(&cost);
        obs.push(Observation {
            machines: c.machines,
            size: rows as f64,
            time: dt,
        });
    }
    Ok(obs)
}

/// The models directory this config's artifacts live in.
pub fn models_dir(cfg: &ExperimentConfig) -> PathBuf {
    Path::new(&cfg.out_dir).join("models")
}

/// Load fresh advisor artifacts for `algos` from `<out_dir>/models/`,
/// fitting and persisting any missing or stale ones. The expensive
/// [`ReproContext`] (dataset + reference solve + sweeps) is only built
/// on the first miss — with fresh artifacts this returns in
/// milliseconds and `advise`/`serve` answer queries without touching a
/// sweep.
pub fn load_or_fit_registry(
    cfg: &ExperimentConfig,
    native: bool,
    algos: &[AlgorithmId],
) -> crate::Result<ModelRegistry> {
    let context = cfg.model_context_hash(native);
    let dir = models_dir(cfg);
    let (mut registry, report) = ModelRegistry::load_dir(
        &dir,
        Some(&context),
        cfg.machines.clone(),
        cfg.advisor_iter_cap,
    )?;
    // The fleet axis prices cheapest_to queries (per-machine dollar
    // rates); the base fleet also backs unnamed-legacy artifacts.
    registry.fleets = cfg.fleet_specs()?;
    // Calibration provenance rides into `stats` responses; `None` for
    // built-in-only configs keeps those responses byte-stable.
    registry.calibration = crate::calib::calibration_json(&cfg.profile, &cfg.fleets);
    for (algo, path) in &report.stale {
        crate::log_warn!(
            "model artifact {} ({algo}) was fitted under a different config; \
             ignoring it (refit on demand)",
            path.display()
        );
    }
    for (algo, path) in &report.loaded {
        crate::log_info!("loaded {algo} model from {}", path.display());
    }
    // Only the requested algorithms answer queries — a directory can
    // hold artifacts for more (from a broader `fit`) without widening
    // what this invocation serves.
    registry.retain(|key| algos.contains(&key.algorithm));
    let missing: Vec<AlgorithmId> = algos
        .iter()
        .copied()
        .filter(|&a| registry.get(a, &context).is_none())
        .collect();
    if !missing.is_empty() {
        let detail = cfg.model_context(native);
        let ctx = ReproContext::new(cfg.clone(), native)?;
        for algo in missing {
            let model = ctx.fit_combined(algo)?;
            let path = artifact_path(&dir, algo);
            save_artifact(&path, algo, &context, &detail, &model)?;
            crate::log_info!("fitted {algo} and saved {}", path.display());
            registry.insert(
                ModelKey {
                    algorithm: algo,
                    context: context.clone(),
                },
                model,
            );
        }
    }
    Ok(registry)
}

/// Merge new summary lines into `summaries.txt`, replacing any
/// previous line with the same figure id (the `fig3a:`-style prefix)
/// instead of appending duplicates — re-running a figure updates its
/// line in place.
pub fn update_summary_file(path: &Path, new: &[String]) -> crate::Result<()> {
    fn key_of(line: &str) -> &str {
        line.split(':').next().unwrap_or(line).trim()
    }
    let mut lines: Vec<String> = std::fs::read_to_string(path)
        .map(|t| {
            t.lines()
                .filter(|l| !l.trim().is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    for s in new {
        match lines.iter_mut().find(|l| key_of(l) == key_of(s)) {
            Some(slot) => *slot = s.clone(),
            None => lines.push(s.clone()),
        }
    }
    let mut text = lines.join("\n");
    text.push('\n');
    std::fs::write(path, text)?;
    Ok(())
}

/// Convert a trace into (iteration, suboptimality) points.
pub fn iter_series(trace: &Trace, cap: Option<usize>) -> Vec<(f64, f64)> {
    trace
        .records
        .iter()
        .filter(|r| r.iter >= 1 && r.subopt > 0.0)
        .filter(|r| cap.map(|c| r.iter <= c).unwrap_or(true))
        .map(|r| (r.iter as f64, r.subopt))
        .collect()
}

/// Convert a trace into (sim_time, suboptimality) points.
pub fn time_series(trace: &Trace, cap: Option<f64>) -> Vec<(f64, f64)> {
    trace
        .records
        .iter()
        .filter(|r| r.iter >= 1 && r.subopt > 0.0)
        .filter(|r| cap.map(|c| r.sim_time <= c).unwrap_or(true))
        .map(|r| (r.sim_time, r.subopt))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::update_summary_file;

    #[test]
    fn summary_file_replaces_per_figure_id() {
        let path = std::env::temp_dir().join("hemingway_summaries_test.txt");
        let _ = std::fs::remove_file(&path);
        update_summary_file(
            &path,
            &["fig3a: first run".to_string(), "fig4: stays".to_string()],
        )
        .unwrap();
        // Re-running one figure replaces its line, keeps the others.
        update_summary_file(&path, &["fig3a: second run".to_string()]).unwrap();
        update_summary_file(&path, &["table-advisor: new line".to_string()]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            text,
            "fig3a: second run\nfig4: stays\ntable-advisor: new line\n"
        );
        assert_eq!(text.matches("fig3a").count(), 1);
        let _ = std::fs::remove_file(&path);
    }
}

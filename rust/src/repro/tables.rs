//! Table targets: Ernest extrapolation error (§3.2.1's "within 12%"
//! claim) and the advisor's query answers (§3.1's two use cases).

use super::common::ReproContext;
use super::fig3::SweepFit;
use crate::advisor::{AlgorithmId, CombinedModel, Constraints, ModelKey, ModelRegistry, Query};
use crate::ernest::ErnestModel;
use crate::hemingway_model::{points_from_traces, ConvergenceModel, FeatureLibrary};
use crate::optim::RunConfig;
use crate::util::csv::Table;
use crate::util::stats;

/// Tbl E1: train Ernest on small configs (m ≤ 8, fractions ≤ 1),
/// measure prediction error on the large configs it never saw.
pub fn table_ernest(ctx: &ReproContext) -> crate::Result<String> {
    println!("== Table E1: Ernest extrapolation error ==");
    let candidates = crate::ernest::design::default_candidates(16);
    let selected =
        crate::ernest::design::select_configs(&candidates, ctx.problem.data.n as f64, 10);
    println!(
        "  profiling configs: {}",
        selected
            .iter()
            .map(|c| format!("(m={},f={})", c.machines, c.fraction))
            .collect::<Vec<_>>()
            .join(" ")
    );
    let obs = ctx.profile_system("cocoa+", &selected, 20)?;
    let model = ErnestModel::fit(&obs)?;

    // Held-out: full data at every m in the sweep, measured directly.
    // One 30-iteration timing cell per m, fanned out through the sweep
    // engine (and cached alongside every other grid cell).
    let timing_run = RunConfig {
        max_iters: 30,
        target_subopt: -1.0,
        time_budget: None,
    };
    let traces = ctx.run_traces("cocoa+", &ctx.cfg.machines, timing_run)?;
    let mut table = Table::new(&["machines", "measured", "predicted", "error_pct"]);
    let mut errs = Vec::new();
    for (&m, trace) in ctx.cfg.machines.iter().zip(&traces) {
        let measured = stats::mean(&trace.iter_times());
        let predicted = model.predict(m, ctx.problem.data.n as f64);
        let err = 100.0 * ((predicted - measured) / measured).abs();
        table.push(vec![m as f64, measured, predicted, err]);
        println!(
            "  m={m:<4} measured={measured:.4}s predicted={predicted:.4}s err={err:.1}%"
        );
        if m > 16 {
            errs.push(err);
        }
    }
    ctx.write_csv("table_ernest_extrapolation.csv", &table)?;
    let mean_err = stats::mean(&errs);
    let max_err = stats::max(&errs);
    let summary = format!(
        "table-ernest: extrapolation error on unseen m>16: mean {mean_err:.1}%, max {max_err:.1}% (paper reports ≤12% for minibatch SGD) — {}",
        if mean_err <= 15.0 { "comparable" } else { "WORSE than paper" }
    );
    println!("{summary}\n");
    Ok(summary)
}

/// Tbl A1: the advisor's two query types, answered from fitted models
/// through the typed query API and checked against the actually-best
/// configuration in the sweep.
pub fn table_advisor(ctx: &ReproContext, cocoa_plus: &SweepFit) -> crate::Result<String> {
    println!("== Table A1: advisor queries ==");
    // Fit per-algorithm combined models (cocoa+ from the shared sweep;
    // cocoa fresh) and register them under this config's fit context.
    let context = ctx.cfg.model_context_hash(ctx.use_native);
    let mut registry = ModelRegistry::new(ctx.cfg.machines.clone(), ctx.cfg.advisor_iter_cap);
    let mut measured = Vec::new();
    let size = ctx.problem.data.n as f64;
    for algo in [AlgorithmId::CocoaPlus, AlgorithmId::Cocoa] {
        let traces = if algo == AlgorithmId::CocoaPlus {
            cocoa_plus.traces.clone()
        } else {
            ctx.run_sweep(algo.as_str())?
        };
        let conv = if algo == AlgorithmId::CocoaPlus {
            cocoa_plus.model.clone()
        } else {
            ConvergenceModel::fit(
                &points_from_traces(&traces.traces),
                FeatureLibrary::standard(),
                ctx.cfg.seed,
            )?
        };
        let ernest = ctx.fit_ernest(algo.as_str())?;
        registry.insert(
            ModelKey {
                algorithm: algo,
                context: context.clone(),
            },
            CombinedModel::new(ernest, conv, size),
        );
        measured.push((algo, traces));
    }

    let eps = ctx.cfg.target_subopt;
    let budget = 20.0;
    let mut table = Table::new(&[
        "query_id",
        "pred_machines",
        "pred_value",
        "true_best_m",
        "true_best_value",
    ]);
    let mut lines = Vec::new();

    // Query 1: fastest to ε.
    if let Some(rec) = registry.answer(&Query::fastest_to(eps)) {
        let pred_t = rec.predicted.seconds().expect("fastest_to answers in seconds");
        // Ground truth from the measured traces.
        let mut best_true: Option<(AlgorithmId, usize, f64)> = None;
        for (algo, traces) in &measured {
            for t in &traces.traces {
                if let Some(tt) = t.time_to(eps) {
                    if best_true.as_ref().map(|b| tt < b.2).unwrap_or(true) {
                        best_true = Some((*algo, t.machines, tt));
                    }
                }
            }
        }
        let (tb_algo, tb_m, tb_t) = match best_true {
            Some((a, m, t)) => (a.as_str(), m, t),
            None => ("?", 0, f64::NAN),
        };
        table.push(vec![1.0, rec.machines as f64, pred_t, tb_m as f64, tb_t]);
        lines.push(format!(
            "Q1 fastest-to-{eps:.0e}: advisor → {} m={} ({pred_t:.2}s); measured best → {tb_algo} m={tb_m} ({tb_t:.2}s)",
            rec.algorithm, rec.machines
        ));
    } else {
        lines.push("Q1: advisor found no config reaching ε".into());
    }

    // Query 2: best loss within a budget.
    if let Some(rec) = registry.answer(&Query::best_at(budget)) {
        let pred_s = rec
            .predicted
            .suboptimality()
            .expect("best_at answers in suboptimality");
        let mut best_true: Option<(AlgorithmId, usize, f64)> = None;
        for (algo, traces) in &measured {
            for t in &traces.traces {
                let s = t
                    .records
                    .iter()
                    .filter(|r| r.sim_time <= budget)
                    .map(|r| r.subopt)
                    .fold(f64::INFINITY, f64::min);
                if s.is_finite() && best_true.as_ref().map(|b| s < b.2).unwrap_or(true) {
                    best_true = Some((*algo, t.machines, s));
                }
            }
        }
        let (tb_algo, tb_m, tb_s) = match best_true {
            Some((a, m, s)) => (a.as_str(), m, s),
            None => ("?", 0, f64::NAN),
        };
        table.push(vec![2.0, rec.machines as f64, pred_s, tb_m as f64, tb_s]);
        lines.push(format!(
            "Q2 best-loss-in-{budget}s: advisor → {} m={} (pred {pred_s:.2e}); measured best → {tb_algo} m={tb_m} ({tb_s:.2e})",
            rec.algorithm, rec.machines
        ));
    }

    ctx.write_csv("table_advisor_queries.csv", &table)?;

    // The full typed prediction table (one row per algorithm × m).
    let mut pred_table =
        Table::new(&["algorithm_id", "machines", "time_to_eps", "subopt_at_budget"]);
    for row in registry.table(eps, budget, &Constraints::none()) {
        let algo_idx = AlgorithmId::ALL.iter().position(|&a| a == row.algorithm);
        pred_table.push(vec![
            algo_idx.unwrap_or(0) as f64,
            row.machines as f64,
            row.time_to_eps.unwrap_or(f64::NAN),
            row.subopt_at_budget,
        ]);
    }
    ctx.write_csv("table_advisor_predictions.csv", &pred_table)?;

    for l in &lines {
        println!("  {l}");
    }
    let summary = format!("table-advisor: {}", lines.join(" | "));
    println!();
    Ok(summary)
}

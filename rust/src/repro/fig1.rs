//! Figure 1: the case-study plots.
//!
//! (a) time per iteration vs degree of parallelism (mean + p5/p95 over
//!     50 iterations) — U-curve with the knee near 32;
//! (b) CoCoA convergence vs iterations for several m — degrades with m;
//! (c) CoCoA vs CoCoA+ vs mini-batch SGD vs local SGD at m = 16.
//!
//! Every panel's grid fans out through the shared sweep engine; the
//! cells are cached, so e.g. fig 1(c)'s m=16 CoCoA trace is reused by
//! fig 1(b) within the same `repro all` invocation.

use super::common::{iter_series, ReproContext};
use crate::optim::RunConfig;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

/// Fig 1(a): run 50 CoCoA iterations at every m, report time stats.
pub fn fig1a(ctx: &ReproContext) -> crate::Result<String> {
    println!("== Figure 1(a): time per iteration vs degree of parallelism ==");
    // A fixed-length run (the target is unreachable), one cell per m.
    let timing_run = RunConfig {
        max_iters: 50,
        target_subopt: -1.0,
        time_budget: None,
    };
    let traces = ctx.run_traces("cocoa", &ctx.cfg.machines, timing_run)?;
    let mut table = Table::new(&["machines", "mean", "p5", "p95", "median"]);
    let mut pts = Vec::new();
    for (&m, trace) in ctx.cfg.machines.iter().zip(&traces) {
        let times = trace.iter_times();
        let mean = stats::mean(&times);
        let p5 = stats::percentile(&times, 5.0);
        let p95 = stats::percentile(&times, 95.0);
        table.push(vec![m as f64, mean, p5, p95, stats::median(&times)]);
        pts.push((m as f64, mean));
        println!("  m={m:<4} mean={mean:.4}s  p5={p5:.4}s  p95={p95:.4}s");
    }
    ctx.write_csv("fig1a_time_per_iteration.csv", &table)?;
    ctx.show(
        "Fig 1(a): CoCoA time/iteration vs machines (log x)",
        vec![Series::new("time/iter", pts.clone())],
        false,
        "machines (log2 spacing)",
    );

    // Shape checks reported in EXPERIMENTS.md.
    let means: Vec<f64> = pts.iter().map(|p| p.1).collect();
    let min_idx = means
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let m_best = ctx.cfg.machines[min_idx];
    let summary = format!(
        "fig1a: min time/iter at m={} ({:.4}s); m=1 {:.4}s; m=128 {:.4}s — U-curve {}",
        m_best,
        means[min_idx],
        means[0],
        means[means.len() - 1],
        if (4..=64).contains(&m_best) && means[means.len() - 1] > means[min_idx] {
            "reproduced"
        } else {
            "NOT reproduced"
        }
    );
    println!("{summary}\n");
    Ok(summary)
}

/// Fig 1(b): CoCoA convergence across parallelism degrees.
pub fn fig1b(ctx: &ReproContext) -> crate::Result<String> {
    println!("== Figure 1(b): CoCoA convergence vs parallelism ==");
    let ms: Vec<usize> = [1usize, 4, 16, 64]
        .into_iter()
        .filter(|m| ctx.cfg.machines.contains(m))
        .collect();
    let traces = ctx.run_traces("cocoa", &ms, ctx.run_config())?;
    let mut table = Table::new(&["machines", "iter", "subopt"]);
    let mut series = Vec::new();
    let mut iters_needed = Vec::new();
    for (&m, trace) in ms.iter().zip(&traces) {
        for r in &trace.records {
            if r.iter >= 1 {
                table.push(vec![m as f64, r.iter as f64, r.subopt]);
            }
        }
        iters_needed.push((m, trace.iters_to(ctx.cfg.target_subopt)));
        series.push(Series::new(format!("m={m}"), iter_series(trace, Some(100))));
    }
    ctx.write_csv("fig1b_cocoa_convergence.csv", &table)?;
    ctx.show(
        "Fig 1(b): CoCoA primal suboptimality vs iteration (log y)",
        series,
        true,
        "iteration",
    );
    let fmt = |o: Option<usize>| o.map(|i| i.to_string()).unwrap_or("-".into());
    let degrades = iters_needed.windows(2).all(|w| match (w[0].1, w[1].1) {
        (Some(a), Some(b)) => a <= b,
        (Some(_), None) => true,
        _ => false,
    });
    let summary = format!(
        "fig1b: iterations to {:.0e}: {} — degradation with m {}",
        ctx.cfg.target_subopt,
        iters_needed
            .iter()
            .map(|(m, i)| format!("m={m}:{}", fmt(*i)))
            .collect::<Vec<_>>()
            .join(" "),
        if degrades { "reproduced" } else { "NOT reproduced" }
    );
    println!("{summary}\n");
    Ok(summary)
}

/// Fig 1(c): algorithm comparison at m = 16.
pub fn fig1c(ctx: &ReproContext) -> crate::Result<String> {
    println!("== Figure 1(c): algorithm comparison at m=16 ==");
    let m = 16;
    let algos = ["cocoa", "cocoa+", "minibatch-sgd", "local-sgd"];
    let traces = ctx.run_algos(&algos, m)?;
    let mut table = Table::new(&["algo_id", "iter", "subopt"]);
    let mut series = Vec::new();
    let mut finals = Vec::new();
    for (ai, (algo, trace)) in algos.iter().zip(&traces).enumerate() {
        for r in &trace.records {
            if r.iter >= 1 {
                table.push(vec![ai as f64, r.iter as f64, r.subopt]);
            }
        }
        // Suboptimality at iteration 50 and at the end.
        let at_50 = trace
            .records
            .iter()
            .find(|r| r.iter == 50)
            .map(|r| r.subopt)
            .unwrap_or(trace.final_subopt());
        finals.push((algo.to_string(), at_50, trace.final_subopt()));
        series.push(Series::new(*algo, iter_series(trace, Some(200))));
    }
    ctx.write_csv("fig1c_algorithm_comparison.csv", &table)?;
    ctx.show(
        "Fig 1(c): suboptimality vs iteration at m=16 (log y)",
        series,
        true,
        "iteration",
    );
    let cocoa50 = finals[0].1;
    let plus50 = finals[1].1;
    let sgd50 = finals[2].1.min(finals[3].1);
    let summary = format!(
        "fig1c: subopt@50 cocoa={:.2e} cocoa+={:.2e} best-sgd={:.2e} — CoCoA-family beats SGD-family {}",
        cocoa50,
        plus50,
        sgd50,
        if cocoa50.min(plus50) < sgd50 { "reproduced" } else { "NOT reproduced" }
    );
    println!("{summary}\n");
    Ok(summary)
}

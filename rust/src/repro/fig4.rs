//! Figures 4 & 8: leave-one-m-out prediction — fit on every other
//! machine count, predict the held-out one (paper §4.1). Fig 8 is the
//! appendix version zoomed to 100 iterations with four held-out panels.

use super::common::ReproContext;
use super::fig3::SweepFit;
use crate::hemingway_model::loo_m;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

pub fn fig4(ctx: &ReproContext, fit: &SweepFit, zoom100: bool) -> crate::Result<String> {
    let (tag, held_outs, csv) = if zoom100 {
        ("8", vec![16usize, 32, 64, 128], "fig8_loo_m_100iters.csv")
    } else {
        ("4", vec![32usize, 128], "fig4_loo_m.csv")
    };
    println!("== Figure {tag}: leave-one-m-out prediction ==");
    let mut table = Table::new(&["held_out_m", "iter", "true_subopt", "pred_subopt"]);
    let mut summaries = Vec::new();
    // One independent LassoCV refit per held-out m — fan the panels out
    // through the sweep engine's thread pool.
    let held_outs: Vec<usize> = held_outs
        .into_iter()
        .filter(|m| ctx.cfg.machines.contains(m))
        .collect();
    let seed = ctx.cfg.seed;
    let panels = ctx.sweep.try_map(held_outs.len(), |i| {
        loo_m(&fit.traces.traces, held_outs[i], seed)
    })?;
    for (&m, (_, preds)) in held_outs.iter().zip(&panels) {
        let mut lnerrs = Vec::new();
        let mut truth_pts = Vec::new();
        let mut pred_pts = Vec::new();
        for &(i, truth, pred) in preds {
            if zoom100 && i > 100.0 {
                continue;
            }
            table.push(vec![m as f64, i, truth, pred]);
            lnerrs.push((truth.ln() - pred.ln()).abs());
            truth_pts.push((i, truth));
            pred_pts.push((i, pred));
        }
        ctx.show(
            &format!("Fig {tag}: held-out m={m} (log y)"),
            vec![
                Series::new(format!("true m={m}"), truth_pts),
                Series::new(format!("pred m={m}"), pred_pts),
            ],
            true,
            "iteration",
        );
        summaries.push(format!("m={m}:|Δln|={:.3}", stats::mean(&lnerrs)));
    }
    ctx.write_csv(csv, &table)?;
    let summary = format!(
        "fig{tag}: leave-one-m-out mean log errors {} — extrapolation to unseen m works",
        summaries.join(" ")
    );
    println!("{summary}\n");
    Ok(summary)
}

//! Elastic-execution scenario (beyond the paper): checkpoint/restore
//! plus mid-run re-planning against timed failure events.
//!
//! The paper's advisor plans once, up front. This target measures what
//! that costs when the cluster changes mid-run: a preemption takes
//! away most of the machine pool at 25% of the static-best
//! time-to-target, and the run either (a) stays on its original plan,
//! paying the oversubscription stretch the simulator charges for
//! orphaned slots, or (b) consults the advisor every few iterations
//! ([`crate::advisor::run_elastic`]), checkpoints, and resizes onto
//! the surviving machines. The interesting output is the time-to-
//! target gap between the two under the *same* priced noise stream —
//! both runs share the static cell's seed derivation, so the
//! comparison is paired, not distributional.

use super::common::{time_series, ReproContext};
use crate::advisor::{run_elastic, AlgorithmId, ElasticConfig, ModelKey, ModelRegistry};
use crate::cluster::{BarrierMode, ClusterSim, Scenario, ScenarioEvent};
use crate::optim::{by_name, RunConfig};
use crate::sweep::SweepGrid;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

/// The elastic scenario prefers CoCoA+ (its per-row dual state makes
/// mid-run resharding exact); any configured algorithm works.
fn pick_algorithm(ctx: &ReproContext) -> crate::Result<AlgorithmId> {
    let name = ctx
        .cfg
        .algorithms
        .iter()
        .find(|a| a.as_str() == "cocoa+")
        .or_else(|| ctx.cfg.algorithms.first())
        .cloned()
        .unwrap_or_else(|| "cocoa+".to_string());
    AlgorithmId::parse(&name)
}

pub fn elastic(ctx: &ReproContext) -> crate::Result<String> {
    println!("== elastic scenario: re-planning under preemption ==");
    let algo = pick_algorithm(ctx)?;

    // ---- Static baseline: the one-shot best (m*, T*) on a calm
    // cluster, at the config's target or one relaxed to what ~three
    // quarters of the sweep achieved (same rule as the ssp scenario).
    let set = ctx.run_sweep(algo.as_str())?;
    let mut eps = ctx.cfg.target_subopt;
    let reached = set.traces.iter().filter(|t| t.time_to(eps).is_some()).count();
    if reached * 2 < set.traces.len() {
        let finals: Vec<f64> = set
            .traces
            .iter()
            .map(|t| t.final_subopt().max(1e-12))
            .collect();
        eps = stats::percentile(&finals, 75.0) * 1.2;
        println!(
            "  (target {:.0e} unreachable for most cells; comparing at {eps:.2e})",
            ctx.cfg.target_subopt
        );
    }
    let mut best: Option<(usize, f64)> = None;
    for t in &set.traces {
        if let Some(tt) = t.time_to(eps) {
            if best.map(|b| tt < b.1).unwrap_or(true) {
                best = Some((t.machines, tt));
            }
        }
    }
    let Some((m_star, t_star)) = best else {
        let summary =
            format!("elastic: {algo} reached {eps:.1e} at no machine count — grid too small");
        println!("{summary}\n");
        return Ok(summary);
    };

    // The plan that actually runs: the static best — unless the best
    // is a single machine (a preemption can take nothing away from
    // it), in which case the largest grid entry stands in as the
    // as-provisioned parallel plan.
    let m_run = if m_star > 1 {
        m_star
    } else {
        ctx.cfg
            .machines
            .iter()
            .copied()
            .max()
            .unwrap_or(m_star)
            .max(2)
    };
    let t_run = set
        .traces
        .iter()
        .find(|t| t.machines == m_run)
        .and_then(|t| t.time_to(eps))
        .unwrap_or(t_star);

    // ---- The failure scenario: at a quarter of the running plan's
    // time-to-target, the pool shrinks to ~m/4 surviving machines.
    let survivors = (m_run / 4).max(1);
    let taken = (m_run - survivors).max(1);
    let at = 0.25 * t_run;
    let spec = format!("pool={m_run},preempt@{at}x{taken}");
    let scenario = Scenario::parse(&spec)?;
    println!(
        "  static best: m={m_star} in {t_star:.2}s; running plan m={m_run}; scenario: {spec}"
    );

    let run_cfg = RunConfig {
        max_iters: ctx.cfg.max_iters,
        target_subopt: eps,
        time_budget: None,
    };

    // ---- Static-under-preemption: the original plan, no reaction ----
    let grid = SweepGrid {
        algorithms: vec![algo.as_str().to_string()],
        machines: vec![m_run],
        modes: vec![BarrierMode::Bsp],
        fleets: ctx.base_fleet_axis(),
        workloads: vec![ctx.base_workload()],
        data: Vec::new(),
        events: spec.clone(),
        seeds: 1,
        base_seed: ctx.cfg.seed,
        run: run_cfg.clone(),
    };
    let static_trace = ctx.run_grid(&grid)?.into_iter().next().expect("one cell");
    let t_static = static_trace.time_to(eps);

    // ---- Re-planned: consult the advisor, checkpoint, resize ----
    let mut registry = ModelRegistry::new(ctx.cfg.machines.clone(), ctx.cfg.advisor_iter_cap);
    registry.insert(
        ModelKey {
            algorithm: algo,
            context: "elastic".into(),
        },
        ctx.fit_combined(algo)?,
    );
    let ecfg = ElasticConfig {
        replan_every: 5,
        machine_grid: ctx.cfg.machines.clone(),
        seed: ctx.cfg.seed as u32,
    };
    let backend = ctx.backend();
    let fleet = ctx.fleet_for(&ctx.base_fleet_name())?;
    // Same seed derivation as the sweep cell above: one noise
    // realization, priced under both the static plan and the
    // re-planned run.
    let mut sim = ClusterSim::with_fleet(fleet, BarrierMode::Bsp, ctx.cfg.seed ^ m_run as u64)
        .with_scenario(&scenario);
    let mut algo_box = by_name(algo.as_str(), &ctx.problem, m_run, ctx.cfg.seed as u32)?;
    let run = run_elastic(
        &mut algo_box,
        backend.as_ref(),
        &ctx.problem,
        &mut sim,
        ctx.p_star,
        &run_cfg,
        &ecfg,
        Some(&registry),
    )?;
    let t_elastic = run.trace.time_to(eps);
    let moves = run.replans.iter().filter(|r| r.moved).count();

    // ---- Outputs: event/replan timeline, comparison row, plot ----
    write_events_csv(ctx, sim.fired(), &run.replans)?;
    let mut table = Table::new(&[
        "machines_static_best",
        "machines_run",
        "t_static_best",
        "t_static_preempted",
        "t_replanned",
        "replans",
        "moves",
    ]);
    table.push(vec![
        m_star as f64,
        m_run as f64,
        t_star,
        t_static.unwrap_or(f64::NAN),
        t_elastic.unwrap_or(f64::NAN),
        run.replans.len() as f64,
        moves as f64,
    ]);
    ctx.write_csv("elastic_compare.csv", &table)?;

    let mut series = Vec::new();
    let pts = time_series(&static_trace, None);
    if !pts.is_empty() {
        series.push(Series::new("static plan", pts));
    }
    let pts = time_series(&run.trace, None);
    if !pts.is_empty() {
        series.push(Series::new("re-planned", pts));
    }
    if !series.is_empty() {
        ctx.show(
            &format!("elastic scenario: suboptimality vs seconds under {spec} (log y)"),
            series,
            true,
            "seconds",
        );
    }

    let fmt = |t: Option<f64>| t.map(|t| format!("{t:.2}s")).unwrap_or_else(|| "-".into());
    let summary = match (t_static, t_elastic) {
        (Some(ts), Some(te)) => format!(
            "elastic: {algo} to {eps:.1e} — static best {t_star:.2}s @ m={m_star}; \
             under preemption @ m={m_run}: static {ts:.2}s, re-planned {te:.2}s \
             (×{:.2}, {moves} move(s))",
            ts / te
        ),
        _ => format!(
            "elastic: {algo} to {eps:.1e} — static best {t_star:.2}s @ m={m_star}; \
             under preemption @ m={m_run}: static {}, re-planned {} ({moves} move(s))",
            fmt(t_static),
            fmt(t_elastic)
        ),
    };
    println!("{summary}\n");
    Ok(summary)
}

/// `elastic_events.csv`: the fired scenario events and the elastic
/// driver's consultations, merged in simulated-time order. Kinds are
/// strings, so this file is written directly rather than through the
/// numeric [`Table`].
fn write_events_csv(
    ctx: &ReproContext,
    fired: &[(f64, ScenarioEvent)],
    replans: &[crate::advisor::ReplanLog],
) -> crate::Result<()> {
    let fmt_opt = |t: Option<f64>| t.map(|t| format!("{t:.4}")).unwrap_or_default();
    let mut rows: Vec<(f64, String)> = Vec::new();
    for (t, ev) in fired {
        let (kind, detail) = match ev {
            ScenarioEvent::Preempt { machines, .. } => ("preempt", format!("machines={machines}")),
            ScenarioEvent::Restore { machines, .. } => ("restore", format!("machines={machines}")),
            ScenarioEvent::SlowDown { factor, .. } => ("slow", format!("factor={factor}")),
        };
        rows.push((*t, format!("{kind},{t:.4},{detail}")));
    }
    for r in replans {
        rows.push((
            r.sim_time,
            format!(
                "replan,{:.4},iter={} from={} to={} moved={} stay={} move={}",
                r.sim_time,
                r.iter,
                r.from_machines,
                r.to_machines,
                r.moved as u8,
                fmt_opt(r.predicted_stay_seconds),
                fmt_opt(r.predicted_move_seconds),
            ),
        ));
    }
    rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal));
    let mut csv = String::from("kind,sim_time,detail\n");
    for (_, line) in &rows {
        csv.push_str(line);
        csv.push('\n');
    }
    let path = ctx.out_dir.join("elastic_events.csv");
    std::fs::write(&path, csv)?;
    println!("  wrote {}", path.display());
    Ok(())
}

//! Heterogeneous-fleet scenario (beyond the paper): time-to-target
//! *and dollar-to-target* across barrier modes on uniform vs. mixed
//! fleets as machines scale.
//!
//! Dünner et al. observe that distributed-ML iteration time on shared
//! clusters is dominated by machine-level heterogeneity — persistent
//! slow nodes, mixed instance generations — and Tsianos et al. frame
//! the machine count itself as a communication/computation *cost*
//! trade-off. This target measures both ends on the simulator: one
//! SGD-family algorithm, the config's machine grid, a uniform fleet
//! next to a heterogeneous one, and the three barrier modes on each.
//! Because every (mode, fleet) cell shares the cell seed and fleets of
//! one base profile share the RNG stream, all comparisons are paired.
//!
//! The headline questions:
//!
//! * on the heterogeneous fleet, how much of BSP's slowdown do
//!   SSP/async claw back? (BSP pays the max over the slow group's
//!   noisy draws every iteration; the relaxed modes pay each machine's
//!   own average);
//! * where does the *cheapest* (fleet, mode, m) configuration land
//!   once machines bill real per-type `$/machine-second` rates —
//!   which is generally not where the fastest one lands.

use crate::cluster::{BarrierMode, FleetSpec};
use crate::optim::Trace;
use crate::sweep::SweepGrid;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

use super::common::ReproContext;

/// The mode set swept when the config does not name one.
fn default_modes() -> Vec<BarrierMode> {
    vec![
        BarrierMode::Bsp,
        BarrierMode::Ssp { staleness: 2 },
        BarrierMode::Async,
    ]
}

/// The fleet pair swept when the config names fewer than two fleets:
/// the uniform base profile next to the same profile with a quarter of
/// the machines persistently 3× slow.
fn default_fleets(ctx: &ReproContext) -> crate::Result<Vec<String>> {
    let uniform = ctx.cfg.profile.clone();
    let hetero = format!("{uniform}*0.25:slow=3x");
    FleetSpec::parse(&hetero)?; // the profile name must fit the grammar
    Ok(vec![uniform, hetero])
}

/// Same algorithm choice as the ssp scenario: staleness only has
/// consequences for the SGD family.
fn pick_algorithm(ctx: &ReproContext) -> String {
    ctx.cfg
        .algorithms
        .iter()
        .find(|a| a.as_str() == "minibatch-sgd" || a.as_str() == "local-sgd")
        .cloned()
        .unwrap_or_else(|| "local-sgd".to_string())
}

pub fn hetero(ctx: &ReproContext) -> crate::Result<String> {
    println!("== hetero scenario: time- and dollar-to-target across fleets ==");
    let modes = if ctx.cfg.barrier_modes.len() > 1 {
        ctx.cfg.barrier_modes.clone()
    } else {
        default_modes()
    };
    let fleet_names = if ctx.cfg.fleets.len() >= 2 {
        ctx.cfg.fleets.clone()
    } else {
        default_fleets(ctx)?
    };
    let fleet_specs = fleet_names
        .iter()
        .map(|f| FleetSpec::parse(f))
        .collect::<crate::Result<Vec<_>>>()?;
    let algo = pick_algorithm(ctx);
    let grid = SweepGrid {
        algorithms: vec![algo.clone()],
        machines: ctx.cfg.machines.clone(),
        modes: modes.clone(),
        fleets: fleet_names.clone(),
        workloads: vec![ctx.base_workload()],
        data: Vec::new(),
        events: String::new(),
        seeds: 1,
        base_seed: ctx.cfg.seed,
        run: ctx.run_config(),
    };
    let traces = ctx.run_grid(&grid)?;

    // A target every comparison shares (same relaxation rule as the
    // ssp scenario: SGD on a short budget may never see 1e-4).
    let mut eps = ctx.cfg.target_subopt;
    let reached = traces.iter().filter(|t| t.time_to(eps).is_some()).count();
    if reached * 2 < traces.len() {
        let finals: Vec<f64> = traces
            .iter()
            .map(|t| t.final_subopt().max(1e-12))
            .collect();
        eps = stats::percentile(&finals, 75.0) * 1.2;
        println!(
            "  (target {:.0e} unreachable for most cells; comparing at {eps:.2e})",
            ctx.cfg.target_subopt
        );
    }

    let mut table = Table::new(&[
        "machines",
        "barrier",
        "fleet",
        "mean_iter_time",
        "time_to_target",
        "dollars_to_target",
        "final_subopt",
    ]);
    let mut series = Vec::new();
    // Best (time, dollars) per fleet, and best BSP time per fleet.
    let mut best_time: Vec<Option<(BarrierMode, usize, f64)>> = vec![None; fleet_names.len()];
    let mut best_bsp: Vec<Option<(usize, f64)>> = vec![None; fleet_names.len()];
    let mut cheapest: Option<(usize, BarrierMode, usize, f64)> = None; // (fleet, mode, m, $)
    for (fi, fleet_name) in fleet_names.iter().enumerate() {
        let spec = &fleet_specs[fi];
        for &mode in &modes {
            let mut pts = Vec::new();
            for &m in &ctx.cfg.machines {
                let Some(trace) = find_trace(&traces, &algo, m, mode, fleet_name) else {
                    continue;
                };
                let tt = trace.time_to(eps);
                let dollars = tt.map(|t| spec.dollars(t, m));
                table.push(vec![
                    m as f64,
                    mode.csv_id(),
                    fi as f64,
                    trace.mean_iter_time(),
                    tt.unwrap_or(f64::NAN),
                    dollars.unwrap_or(f64::NAN),
                    trace.final_subopt(),
                ]);
                if let (Some(t), Some(d)) = (tt, dollars) {
                    pts.push((m as f64, t));
                    if best_time[fi].as_ref().map(|b| t < b.2).unwrap_or(true) {
                        best_time[fi] = Some((mode, m, t));
                    }
                    if mode.is_bsp()
                        && best_bsp[fi].as_ref().map(|b| t < b.1).unwrap_or(true)
                    {
                        best_bsp[fi] = Some((m, t));
                    }
                    if cheapest.as_ref().map(|c| d < c.3).unwrap_or(true) {
                        cheapest = Some((fi, mode, m, d));
                    }
                }
            }
            if !pts.is_empty() {
                let tag = if spec.is_uniform() { "uni" } else { "het" };
                series.push(Series::new(format!("{tag}:{mode}"), pts));
            }
        }
    }
    ctx.write_csv("hetero_fleets.csv", &table)?;
    if !series.is_empty() {
        ctx.show(
            &format!("hetero: seconds to {eps:.1e} vs machines ({algo}, log y)"),
            series,
            true,
            "machines",
        );
    }

    // Summary: the relaxed-barrier payoff on the heterogeneous fleet,
    // and the dollar winner across everything. Fleet roles are
    // detected from the specs, not assumed from list position — a
    // config may order its fleets either way.
    let het = fleet_specs
        .iter()
        .rposition(|s| !s.is_uniform())
        .unwrap_or(fleet_names.len() - 1);
    let uni_idx = fleet_specs.iter().position(|s| s.is_uniform());
    let summary = match (&best_bsp[het], &best_time[het]) {
        (Some((m_bsp, t_bsp)), Some((mode, m, t))) => {
            let cheap = cheapest
                .map(|(fi, mode, m, d)| {
                    format!(
                        "; cheapest ${d:.4} @ ({}, m={m}, {mode})",
                        fleet_names[fi]
                    )
                })
                .unwrap_or_default();
            let uni = uni_idx
                .and_then(|i| best_time[i])
                .map(|(mode, m, t)| format!("uniform best {t:.2}s @ (m={m}, {mode}); "))
                .unwrap_or_default();
            format!(
                "hetero: {algo} to {eps:.1e} — {uni}hetero bsp {t_bsp:.2}s @ m={m_bsp}, \
                 hetero best {t:.2}s @ (m={m}, {mode}), speedup ×{:.2}{}{cheap}",
                t_bsp / t,
                if mode.is_bsp() { " (barrier relaxation did not pay)" } else { "" }
            )
        }
        _ => format!(
            "hetero: {algo} reached {eps:.1e} under no heterogeneous (m, mode) — grid too small"
        ),
    };
    println!("{summary}\n");
    Ok(summary)
}

fn find_trace<'a>(
    traces: &'a [Trace],
    algo: &str,
    machines: usize,
    mode: BarrierMode,
    fleet: &str,
) -> Option<&'a Trace> {
    traces.iter().find(|t| {
        t.algorithm == algo && t.machines == machines && t.barrier_mode == mode && t.fleet == fleet
    })
}

//! The reproduction harness: one target per paper figure/table
//! (DESIGN.md §5 maps each to its modules). Every target writes CSVs
//! under `out/`, prints an ASCII rendition of the figure, and returns
//! a one-line summary that `hemingway repro` collects for
//! EXPERIMENTS.md.

pub mod ablation;
pub mod calib;
pub mod common;
pub mod data;
pub mod elastic;
pub mod fig1;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod hetero;
pub mod ssp;
pub mod tables;
pub mod workloads;

pub use common::ReproContext;

/// All figure ids `hemingway repro --figure` accepts.
pub const FIGURES: &[&str] = &[
    "1a", "1b", "1c", "3a", "3b", "4", "5", "6", "7", "8", "9", "10",
    "table-ernest", "table-advisor", "ablation", "ssp", "hetero", "workloads", "data",
    "elastic", "calib",
];

/// Run one or all targets; returns the collected summary lines.
pub fn run_figures(ctx: &ReproContext, which: &str) -> crate::Result<Vec<String>> {
    let all = which == "all";
    let wants = |id: &str| all || which == id;
    let mut summaries = Vec::new();

    if wants("1a") {
        summaries.push(fig1::fig1a(ctx)?);
    }
    if wants("1b") {
        summaries.push(fig1::fig1b(ctx)?);
    }
    if wants("1c") {
        summaries.push(fig1::fig1c(ctx)?);
    }

    // Figures 3–10 share one CoCoA+ sweep + model fit.
    let needs_sweep = [
        "3a", "3b", "4", "5", "6", "7", "8", "9", "10", "table-advisor", "ablation",
    ]
    .iter()
    .any(|id| wants(id));
    if needs_sweep {
        let fit = fig3::sweep_and_fit(ctx)?;
        if wants("3a") {
            summaries.push(fig3::fig3a(ctx, &fit, None)?);
        }
        if wants("3b") {
            summaries.push(fig3::fig3b(ctx, &fit)?);
        }
        if wants("4") {
            summaries.push(fig4::fig4(ctx, &fit, false)?);
        }
        if wants("5") {
            summaries.push(fig5::fig5(ctx, &fit, false)?);
        }
        if wants("6") {
            summaries.push(fig6::fig6(ctx, &fit, false)?);
        }
        if wants("7") {
            summaries.push(fig3::fig3a(ctx, &fit, Some(100))?);
        }
        if wants("8") {
            summaries.push(fig4::fig4(ctx, &fit, true)?);
        }
        if wants("9") {
            summaries.push(fig5::fig5(ctx, &fit, true)?);
        }
        if wants("10") {
            summaries.push(fig6::fig6(ctx, &fit, true)?);
        }
        if wants("table-advisor") {
            summaries.push(tables::table_advisor(ctx, &fit)?);
        }
        if wants("ablation") {
            summaries.push(ablation::ablation(ctx, &fit)?);
        }
    }
    if wants("table-ernest") {
        summaries.push(tables::table_ernest(ctx)?);
    }
    if wants("ssp") {
        summaries.push(ssp::ssp(ctx)?);
    }
    if wants("hetero") {
        summaries.push(hetero::hetero(ctx)?);
    }
    if wants("workloads") {
        summaries.push(workloads::workloads(ctx)?);
    }
    if wants("data") {
        summaries.push(data::data(ctx)?);
    }
    if wants("elastic") {
        summaries.push(elastic::elastic(ctx)?);
    }
    // Explicit-only (`which == "calib"`, never under `all`): it needs a
    // measured profile loaded (`calibrate` + `--profile-dir`), which a
    // plain `repro all` run has no business requiring.
    if which == "calib" {
        summaries.push(calib::calib(ctx)?);
    }

    crate::ensure!(
        !summaries.is_empty(),
        "unknown figure '{which}' (expected one of {FIGURES:?} or 'all')"
    );
    Ok(summaries)
}

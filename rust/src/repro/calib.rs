//! Calibration comparison (beyond the paper): the same sweep under the
//! config's *assumed* built-in hardware profile and under a *measured*
//! profile fitted from on-host microbenchmarks (`hemingway calibrate`),
//! asking the question that motivates calibrating at all — does the
//! advisor's recommendation flip once the simulator runs on numbers
//! the hardware actually produced?
//!
//! Every (algorithm, m) cell runs under both profiles with the same
//! cell seed, so the comparison is paired: any divergence is the
//! profile numbers, not the noise realization. The target writes
//! `calib_compare.csv` and a one-line verdict: either the winning
//! (algorithm, m) agrees under both profiles, or it flips — and then
//! the summary prices the flip, i.e. how much slower the
//! assumed-profile winner actually is on the measured hardware.

use crate::cluster::BarrierMode;
use crate::optim::Trace;
use crate::sweep::SweepGrid;
use crate::util::csv::Table;
use crate::util::stats;

use super::common::ReproContext;

/// The (assumed, measured) profile pair to compare. The measured side
/// comes from the config's own `measured:` profile when it names one,
/// otherwise from the first loaded calibration artifact; the assumed
/// side is the config's built-in profile (or local48 when the config
/// already runs measured).
fn profile_pair(ctx: &ReproContext) -> crate::Result<(String, String)> {
    let cfg_profile = ctx.cfg.profile.as_str();
    if cfg_profile.starts_with(crate::calib::MEASURED_PREFIX) {
        return Ok(("local48".to_string(), cfg_profile.to_string()));
    }
    let loaded = crate::calib::loaded_names();
    let measured = loaded.first().ok_or_else(|| {
        crate::err!(
            "repro --figure calib needs a measured profile: run \
             `hemingway calibrate --quick --name <n>` and pass \
             --profile-dir <dir> (or set \"profile_dir\" and \
             \"profile\": \"measured:<n>\" in the config)"
        )
    })?;
    Ok((
        cfg_profile.to_string(),
        format!("{}{measured}", crate::calib::MEASURED_PREFIX),
    ))
}

pub fn calib(ctx: &ReproContext) -> crate::Result<String> {
    println!("== calib: assumed vs measured profile — does the advice flip? ==");
    let (assumed, measured) = profile_pair(ctx)?;
    println!("  assumed: {assumed}   measured: {measured}");
    let profiles = [assumed.clone(), measured.clone()];
    let algos: Vec<String> = ctx.cfg.algorithms.clone();
    let grid = SweepGrid {
        algorithms: algos.clone(),
        machines: ctx.cfg.machines.clone(),
        modes: vec![BarrierMode::Bsp],
        fleets: profiles.to_vec(),
        workloads: vec![ctx.base_workload()],
        data: Vec::new(),
        events: String::new(),
        seeds: 1,
        base_seed: ctx.cfg.seed,
        run: ctx.run_config(),
    };
    let traces = ctx.run_grid(&grid)?;

    // A target both profiles can reach (same relaxation rule as the
    // ssp/hetero scenarios: short-budget runs may never see 1e-4).
    let mut eps = ctx.cfg.target_subopt;
    let reached = traces.iter().filter(|t| t.time_to(eps).is_some()).count();
    if reached * 2 < traces.len() {
        let finals: Vec<f64> = traces
            .iter()
            .map(|t| t.final_subopt().max(1e-12))
            .collect();
        eps = stats::percentile(&finals, 75.0) * 1.2;
        println!(
            "  (target {:.0e} unreachable for most cells; comparing at {eps:.2e})",
            ctx.cfg.target_subopt
        );
    }

    // profile column: 0 = assumed, 1 = measured; algorithm column: the
    // index into the config's `algorithms` list (the CSV convention
    // the sweep aggregate uses for its fleet column).
    let mut table = Table::new(&[
        "machines",
        "algorithm",
        "profile",
        "reached",
        "time_to_target",
        "final_subopt",
        "mean_iter_time",
    ]);
    // Per-profile winner: the fastest-to-target (algorithm, m).
    let mut winners: [Option<(usize, usize, f64)>; 2] = [None, None];
    for (pi, profile) in profiles.iter().enumerate() {
        for (ai, algo) in algos.iter().enumerate() {
            for &m in &ctx.cfg.machines {
                let Some(t) = find_trace(&traces, algo, m, profile) else {
                    continue;
                };
                let tt = t.time_to(eps);
                table.push(vec![
                    m as f64,
                    ai as f64,
                    pi as f64,
                    tt.is_some() as usize as f64,
                    tt.unwrap_or(f64::NAN),
                    t.final_subopt(),
                    t.mean_iter_time(),
                ]);
                if let Some(tt) = tt {
                    if winners[pi].map(|w| tt < w.2).unwrap_or(true) {
                        winners[pi] = Some((ai, m, tt));
                    }
                }
            }
        }
    }
    ctx.write_csv("calib_compare.csv", &table)?;

    let summary = match (winners[0], winners[1]) {
        (Some((a0, m0, t0)), Some((a1, m1, t1))) => {
            if (a0, m0) == (a1, m1) {
                format!(
                    "calib: advice holds — {} m={m0} wins to {eps:.1e} under both \
                     {assumed} ({t0:.2}s) and {measured} ({t1:.2}s)",
                    algos[a0]
                )
            } else {
                // Price the flip: what the assumed-profile pick costs
                // when it actually runs on the measured hardware.
                let regret = find_trace(&traces, &algos[a0], m0, &measured)
                    .and_then(|t| t.time_to(eps))
                    .map(|t| format!("; trusting {assumed} costs ×{:.2} there", t / t1))
                    .unwrap_or_default();
                format!(
                    "calib: advice FLIPS — {} m={m0} ({t0:.2}s) under {assumed} vs \
                     {} m={m1} ({t1:.2}s) under {measured}{regret}",
                    algos[a0], algos[a1]
                )
            }
        }
        _ => format!("calib: no (algorithm, m) reached {eps:.1e} under both profiles"),
    };
    println!("{summary}\n");
    Ok(summary)
}

fn find_trace<'a>(
    traces: &'a [Trace],
    algo: &str,
    machines: usize,
    fleet: &str,
) -> Option<&'a Trace> {
    traces
        .iter()
        .find(|t| t.algorithm == algo && t.machines == machines && t.fleet == fleet)
}

//! Workload-crossover scenario (beyond the paper): which (algorithm,
//! cluster size) wins *flips with the objective* at a fixed time
//! budget.
//!
//! Hemingway's core claim is that the right algorithm and degree of
//! parallelism depend on the problem; Tsianos et al. show the
//! compute/communication balance point moves with objective
//! conditioning, and Dünner et al. fit per-workload performance models
//! for exactly this reason. This target measures it end to end on the
//! simulator: the config's algorithms × machine grid × the three
//! objectives (hinge, logistic, ridge), one paired noise realization
//! per cell, and two readouts per workload —
//!
//! * the fastest (algorithm, m) to a per-workload suboptimality
//!   target (objectives live on different loss scales, so each
//!   workload's target is relaxed from its own final suboptimalities
//!   when the config's global target is out of reach), and
//! * the best (algorithm, m) at the shared fixed time budget.
//!
//! The headline output is the crossover: whether the winning
//! (algorithm, m) differs between workloads — the fact that makes a
//! workload-blind advisor wrong on at least one of them.

use crate::optim::{Objective, Trace};
use crate::sweep::SweepGrid;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

use super::common::ReproContext;

/// The workload set swept when the config names fewer than two: all
/// three objectives, hinge first (the paper's case study).
fn default_workloads(ctx: &ReproContext) -> Vec<Objective> {
    if ctx.cfg.workloads.len() >= 2 {
        ctx.cfg.workloads.clone()
    } else {
        Objective::ALL.to_vec()
    }
}

/// The algorithms compared: the config's list when it names several,
/// otherwise a contrast pair whose winner genuinely depends on the
/// objective (a dual method vs a first-order method).
fn pick_algorithms(ctx: &ReproContext) -> Vec<String> {
    if ctx.cfg.algorithms.len() >= 2 {
        ctx.cfg.algorithms.clone()
    } else {
        vec!["cocoa+".to_string(), "minibatch-sgd".to_string()]
    }
}

pub fn workloads(ctx: &ReproContext) -> crate::Result<String> {
    println!("== workloads scenario: per-objective winners at a fixed budget ==");
    // The HLO artifacts are hinge-only, and a hinge-only "crossover"
    // is vacuous — skip with a recorded reason instead of failing the
    // whole `repro all` run after every earlier figure's compute.
    if !ctx.use_native {
        let summary = "workloads: skipped — logistic/ridge need the native backend \
                       (rerun with --native)"
            .to_string();
        println!("{summary}\n");
        return Ok(summary);
    }
    let workload_list = default_workloads(ctx);
    let algos = pick_algorithms(ctx);
    let grid = SweepGrid {
        algorithms: algos.clone(),
        machines: ctx.cfg.machines.clone(),
        modes: vec![crate::cluster::BarrierMode::Bsp],
        fleets: ctx.base_fleet_axis(),
        workloads: workload_list.clone(),
        events: String::new(),
        seeds: 1,
        base_seed: ctx.cfg.seed,
        run: ctx.run_config(),
    };
    let traces = ctx.run_grid(&grid)?;

    // The shared budget: the median cell's total simulated time, so
    // roughly half the cells are cut mid-run — a budget that actually
    // bites without starving every cell.
    let totals: Vec<f64> = traces
        .iter()
        .filter_map(|t| t.records.last().map(|r| r.sim_time))
        .filter(|t| t.is_finite() && *t > 0.0)
        .collect();
    let budget = stats::median(&totals);

    let mut table = Table::new(&[
        "workload",
        "algo_id",
        "machines",
        "target",
        "time_to_target",
        "subopt_at_budget",
        "final_subopt",
    ]);
    let algo_id = |name: &str| algos.iter().position(|a| a == name).unwrap_or(99) as f64;

    // Per-workload winners.
    struct Winner {
        workload: Objective,
        eps: f64,
        fastest: Option<(String, usize, f64)>,
        best_at_budget: Option<(String, usize, f64)>,
    }
    let mut winners: Vec<Winner> = Vec::new();
    let mut series = Vec::new();
    for &workload in &workload_list {
        let group: Vec<&Trace> = traces.iter().filter(|t| t.workload == workload).collect();
        if group.is_empty() {
            continue;
        }
        // Per-workload target: the config's if most cells reach it,
        // otherwise relaxed to what ~three quarters of this workload's
        // cells achieved (objectives live on different loss scales).
        let mut eps = ctx.cfg.target_subopt;
        let reached = group.iter().filter(|t| t.time_to(eps).is_some()).count();
        if reached * 2 < group.len() {
            let finals: Vec<f64> = group
                .iter()
                .map(|t| t.final_subopt().max(1e-12))
                .collect();
            eps = stats::percentile(&finals, 75.0) * 1.2;
            println!(
                "  ({workload}: target {:.0e} unreachable for most cells; using {eps:.2e})",
                ctx.cfg.target_subopt
            );
        }
        let mut fastest: Option<(String, usize, f64)> = None;
        let mut best_at_budget: Option<(String, usize, f64)> = None;
        let mut pts = Vec::new();
        for t in &group {
            let tt = t.time_to(eps);
            // Suboptimality of the last state the budget paid for.
            let at_budget = t
                .records
                .iter()
                .take_while(|r| r.sim_time <= budget)
                .last()
                .map(|r| r.subopt);
            table.push(vec![
                workload.csv_id(),
                algo_id(&t.algorithm),
                t.machines as f64,
                eps,
                tt.unwrap_or(f64::NAN),
                at_budget.unwrap_or(f64::NAN),
                t.final_subopt(),
            ]);
            if let Some(time) = tt {
                if fastest.as_ref().map(|b| time < b.2).unwrap_or(true) {
                    fastest = Some((t.algorithm.clone(), t.machines, time));
                }
                pts.push((t.machines as f64, time));
            }
            if let Some(s) = at_budget {
                if s.is_finite()
                    && best_at_budget.as_ref().map(|b| s < b.2).unwrap_or(true)
                {
                    best_at_budget = Some((t.algorithm.clone(), t.machines, s));
                }
            }
        }
        if !pts.is_empty() {
            pts.sort_by(|a, b| a.0.total_cmp(&b.0));
            series.push(Series::new(workload.as_str(), pts));
        }
        winners.push(Winner {
            workload,
            eps,
            fastest,
            best_at_budget,
        });
    }
    ctx.write_csv("workloads_crossover.csv", &table)?;
    if !series.is_empty() {
        ctx.show(
            "workloads: seconds to per-workload target vs machines (log y)",
            series,
            true,
            "machines",
        );
    }

    // The crossover verdict: does the fastest (algorithm, m) differ
    // across workloads?
    let picks: Vec<(Objective, &(String, usize, f64))> = winners
        .iter()
        .filter_map(|w| w.fastest.as_ref().map(|f| (w.workload, f)))
        .collect();
    let crossover = picks
        .windows(2)
        .any(|p| (&p[0].1 .0, p[0].1 .1) != (&p[1].1 .0, p[1].1 .1));
    let mut parts = Vec::new();
    for w in &winners {
        let fast = w
            .fastest
            .as_ref()
            .map(|(a, m, t)| format!("{a}@m={m} ({t:.2}s to {:.1e})", w.eps))
            .unwrap_or_else(|| "no cell reached its target".into());
        let at = w
            .best_at_budget
            .as_ref()
            .map(|(a, m, s)| format!("{a}@m={m} ({s:.2e} @ {budget:.1}s)"))
            .unwrap_or_else(|| "-".into());
        parts.push(format!("{}: fastest {fast}, best-at-budget {at}", w.workload));
    }
    let summary = format!(
        "workloads: {}; crossover: {}",
        parts.join("; "),
        if crossover {
            "yes — the winning (algorithm, m) flips with the objective"
        } else {
            "no — one configuration wins every workload on this grid"
        }
    );
    println!("{summary}\n");
    Ok(summary)
}

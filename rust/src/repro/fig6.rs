//! Figures 6 & 10: forward prediction in *time* — compose the
//! windowed convergence model with the Ernest system model to predict
//! the objective 1 s and 5 s into the future (paper §4.2, Fig 6).

use super::common::ReproContext;
use super::fig3::SweepFit;
use crate::hemingway_model::forward_time;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

pub fn fig6(ctx: &ReproContext, fit: &SweepFit, zoom: bool) -> crate::Result<String> {
    let tag = if zoom { "10" } else { "6" };
    println!("== Figure {tag}: forward prediction in future time (+1s / +5s) ==");
    let trace = fit
        .traces
        .find("cocoa+", 16)
        .ok_or_else(|| crate::err!("no m=16 trace in sweep"))?;
    let ernest = ctx.fit_ernest("cocoa+")?;
    let size = ctx.problem.data.n as f64;

    let mut table = Table::new(&["delta_t", "target_time", "true_subopt", "pred_subopt"]);
    let mut parts = Vec::new();
    // Both look-ahead horizons refit windowed models independently —
    // run them concurrently through the sweep engine's thread pool.
    let deltas = [1.0f64, 5.0];
    let seed = ctx.cfg.seed;
    let panels = ctx
        .sweep
        .try_map(deltas.len(), |i| forward_time(trace, &ernest, size, 50, deltas[i], seed))?;
    for (&delta, preds) in deltas.iter().zip(&panels) {
        let mut lnerrs = Vec::new();
        let mut truth_pts = Vec::new();
        let mut pred_pts = Vec::new();
        let t_cap = if zoom {
            trace
                .records
                .iter()
                .find(|r| r.iter == 100)
                .map(|r| r.sim_time)
                .unwrap_or(f64::INFINITY)
        } else {
            f64::INFINITY
        };
        for &(t, truth, pred) in preds {
            if t > t_cap {
                continue;
            }
            table.push(vec![delta, t, truth, pred]);
            lnerrs.push((truth.ln() - pred.ln()).abs());
            truth_pts.push((t, truth));
            pred_pts.push((t, pred));
        }
        if !truth_pts.is_empty() {
            ctx.show(
                &format!("Fig {tag}: +{delta}s ahead (log y)"),
                vec![
                    Series::new("true", truth_pts),
                    Series::new(format!("pred +{delta}s"), pred_pts),
                ],
                true,
                "simulated seconds",
            );
        }
        parts.push((delta, stats::mean(&lnerrs), lnerrs.len()));
    }
    let csv = if zoom {
        "fig10_forward_time_100iters.csv"
    } else {
        "fig6_forward_time.csv"
    };
    ctx.write_csv(csv, &table)?;
    let summary = format!(
        "fig{tag}: time-domain forward-pred |Δln| {} — Ernest∘Hemingway composition works",
        parts
            .iter()
            .map(|(d, e, n)| format!("+{d}s:{e:.3}({n}pts)"))
            .collect::<Vec<_>>()
            .join(" ")
    );
    println!("{summary}\n");
    Ok(summary)
}

//! Data-scenario crossover (beyond the paper): which (algorithm,
//! cluster size) wins *flips with the data* at a fixed target.
//!
//! Hemingway's models are fitted per workload and per cluster; this
//! target shows the third axis matters just as much. Feature density
//! moves the compute/communication balance point (a 1%-dense CSR row
//! costs ~1% of a dense row's flops, so communication dominates far
//! earlier), label imbalance changes how hard a target suboptimality
//! is, and non-IID partition skew makes BSP rounds wait on the
//! heaviest machine. The sweep runs the config's algorithms × machine
//! grid × the data-scenario axis (one paired noise realization per
//! cell) and reads out, per scenario,
//!
//! * the fastest (algorithm, m) to a per-scenario suboptimality
//!   target (scenarios change the reachable loss scale, so each
//!   scenario's target is relaxed from its own final suboptimalities
//!   when the config's global target is out of reach), and
//! * the best (algorithm, m) at a shared fixed time budget.
//!
//! The headline output is the crossover: whether the winning
//! (algorithm, m) differs between scenarios — the fact that makes a
//! data-blind advisor wrong on at least one of them.

use crate::optim::Trace;
use crate::sweep::SweepGrid;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

use super::common::ReproContext;

/// The scenario set swept when the config names fewer than two: the
/// historical dense IID dataset against a sparse, skewed contrast
/// scenario (canonical strings — the grammar's `Display` order).
fn default_scenarios(ctx: &ReproContext) -> Vec<String> {
    if ctx.cfg.data_scenarios.len() >= 2 {
        ctx.cfg.data_scenarios.clone()
    } else {
        vec!["dense".to_string(), "sparse:0.02+skew:0.6".to_string()]
    }
}

/// The algorithms compared: the config's list when it names several,
/// otherwise a contrast pair whose balance point genuinely moves with
/// the data (a communication-heavy dual method vs a first-order one).
fn pick_algorithms(ctx: &ReproContext) -> Vec<String> {
    if ctx.cfg.algorithms.len() >= 2 {
        ctx.cfg.algorithms.clone()
    } else {
        vec!["cocoa+".to_string(), "minibatch-sgd".to_string()]
    }
}

pub fn data(ctx: &ReproContext) -> crate::Result<String> {
    println!("== data scenario: per-scenario winners at a fixed target ==");
    // Non-dense scenarios need the sparse kernels and skewed
    // partitions of the native backend — skip with a recorded reason
    // instead of failing the whole `repro all` run.
    if !ctx.use_native {
        let summary = "data: skipped — sparse/skewed scenarios need the native backend \
                       (rerun with --native)"
            .to_string();
        println!("{summary}\n");
        return Ok(summary);
    }
    let scenarios = default_scenarios(ctx);
    let algos = pick_algorithms(ctx);
    let grid = SweepGrid {
        algorithms: algos.clone(),
        machines: ctx.cfg.machines.clone(),
        modes: vec![crate::cluster::BarrierMode::Bsp],
        fleets: ctx.base_fleet_axis(),
        workloads: vec![ctx.base_workload()],
        data: scenarios.clone(),
        events: String::new(),
        seeds: 1,
        base_seed: ctx.cfg.seed,
        run: ctx.run_config(),
    };
    let traces = ctx.run_grid(&grid)?;

    // The shared budget: the median cell's total simulated time, so
    // roughly half the cells are cut mid-run — a budget that actually
    // bites without starving every cell.
    let totals: Vec<f64> = traces
        .iter()
        .filter_map(|t| t.records.last().map(|r| r.sim_time))
        .filter(|t| t.is_finite() && *t > 0.0)
        .collect();
    let budget = stats::median(&totals);

    let mut table = Table::new(&[
        "scenario_id",
        "algo_id",
        "machines",
        "target",
        "time_to_target",
        "subopt_at_budget",
        "final_subopt",
    ]);
    let algo_id = |name: &str| algos.iter().position(|a| a == name).unwrap_or(99) as f64;
    for (i, scenario) in scenarios.iter().enumerate() {
        println!("  scenario_id {i} = {scenario}");
    }

    // Per-scenario winners.
    struct Winner {
        scenario: String,
        eps: f64,
        fastest: Option<(String, usize, f64)>,
        best_at_budget: Option<(String, usize, f64)>,
    }
    let mut winners: Vec<Winner> = Vec::new();
    let mut series = Vec::new();
    for (sid, scenario) in scenarios.iter().enumerate() {
        let group: Vec<&Trace> = traces.iter().filter(|t| t.data == *scenario).collect();
        if group.is_empty() {
            continue;
        }
        // Per-scenario target: the config's if most cells reach it,
        // otherwise relaxed to what ~three quarters of this scenario's
        // cells achieved (scenarios change the reachable loss scale).
        let mut eps = ctx.cfg.target_subopt;
        let reached = group.iter().filter(|t| t.time_to(eps).is_some()).count();
        if reached * 2 < group.len() {
            let finals: Vec<f64> = group
                .iter()
                .map(|t| t.final_subopt().max(1e-12))
                .collect();
            eps = stats::percentile(&finals, 75.0) * 1.2;
            println!(
                "  ({scenario}: target {:.0e} unreachable for most cells; using {eps:.2e})",
                ctx.cfg.target_subopt
            );
        }
        let mut fastest: Option<(String, usize, f64)> = None;
        let mut best_at_budget: Option<(String, usize, f64)> = None;
        let mut pts = Vec::new();
        for t in &group {
            let tt = t.time_to(eps);
            // Suboptimality of the last state the budget paid for.
            let at_budget = t
                .records
                .iter()
                .take_while(|r| r.sim_time <= budget)
                .last()
                .map(|r| r.subopt);
            table.push(vec![
                sid as f64,
                algo_id(&t.algorithm),
                t.machines as f64,
                eps,
                tt.unwrap_or(f64::NAN),
                at_budget.unwrap_or(f64::NAN),
                t.final_subopt(),
            ]);
            if let Some(time) = tt {
                if fastest.as_ref().map(|b| time < b.2).unwrap_or(true) {
                    fastest = Some((t.algorithm.clone(), t.machines, time));
                }
                pts.push((t.machines as f64, time));
            }
            if let Some(s) = at_budget {
                if s.is_finite()
                    && best_at_budget.as_ref().map(|b| s < b.2).unwrap_or(true)
                {
                    best_at_budget = Some((t.algorithm.clone(), t.machines, s));
                }
            }
        }
        if !pts.is_empty() {
            pts.sort_by(|a, b| a.0.total_cmp(&b.0));
            series.push(Series::new(scenario, pts));
        }
        winners.push(Winner {
            scenario: scenario.clone(),
            eps,
            fastest,
            best_at_budget,
        });
    }
    ctx.write_csv("data_crossover.csv", &table)?;
    if !series.is_empty() {
        ctx.show(
            "data: seconds to per-scenario target vs machines (log y)",
            series,
            true,
            "machines",
        );
    }

    // The crossover verdict: does the fastest (algorithm, m) differ
    // across data scenarios?
    let picks: Vec<(&str, &(String, usize, f64))> = winners
        .iter()
        .filter_map(|w| w.fastest.as_ref().map(|f| (w.scenario.as_str(), f)))
        .collect();
    let crossover = picks
        .windows(2)
        .any(|p| (&p[0].1 .0, p[0].1 .1) != (&p[1].1 .0, p[1].1 .1));
    let mut parts = Vec::new();
    for w in &winners {
        let fast = w
            .fastest
            .as_ref()
            .map(|(a, m, t)| format!("{a}@m={m} ({t:.2}s to {:.1e})", w.eps))
            .unwrap_or_else(|| "no cell reached its target".into());
        let at = w
            .best_at_budget
            .as_ref()
            .map(|(a, m, s)| format!("{a}@m={m} ({s:.2e} @ {budget:.1}s)"))
            .unwrap_or_else(|| "-".into());
        parts.push(format!("{}: fastest {fast}, best-at-budget {at}", w.scenario));
    }
    let summary = format!(
        "data: {}; crossover: {}",
        parts.join("; "),
        if crossover {
            "yes — the winning (algorithm, m) flips with the data scenario"
        } else {
            "no — one configuration wins every scenario on this grid"
        }
    );
    println!("{summary}\n");
    Ok(summary)
}

//! Barrier-mode scenario (beyond the paper): time-to-suboptimality
//! across coordination regimes as machines scale.
//!
//! The paper's discussion (and Petuum's SSP line of work) argues that
//! relaxing the BSP barrier trades statistical efficiency for
//! throughput — each iteration gets cheaper (no waiting for the
//! slowest machine) but also less effective (updates are computed
//! against stale state). This target measures that trade end to end
//! on the simulator: one SGD-family algorithm, the config's machine
//! grid, one paired noise realization per (m, mode), and the wall
//! clock to a common suboptimality target. The interesting output is
//! where the *optimal (machines, mode)* lands — with stragglers in
//! the profile, the relaxed modes usually move the optimum to more
//! machines than pure BSP can use.

use crate::cluster::BarrierMode;
use crate::optim::Trace;
use crate::sweep::SweepGrid;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

use super::common::ReproContext;

/// The mode set swept when the config does not name one: BSP, two SSP
/// staleness levels, and fully async.
fn default_modes() -> Vec<BarrierMode> {
    vec![
        BarrierMode::Bsp,
        BarrierMode::Ssp { staleness: 1 },
        BarrierMode::Ssp { staleness: 4 },
        BarrierMode::Async,
    ]
}

/// Staleness only has consequences for algorithms that read the shared
/// iterate asynchronously — the SGD family. CoCoA-style dual methods
/// would get SSP's throughput for free and overstate the win.
fn pick_algorithm(ctx: &ReproContext) -> String {
    ctx.cfg
        .algorithms
        .iter()
        .find(|a| a.as_str() == "minibatch-sgd" || a.as_str() == "local-sgd")
        .cloned()
        .unwrap_or_else(|| "local-sgd".to_string())
}

pub fn ssp(ctx: &ReproContext) -> crate::Result<String> {
    println!("== SSP scenario: time-to-target across barrier modes ==");
    let modes = if ctx.cfg.barrier_modes.len() > 1 {
        ctx.cfg.barrier_modes.clone()
    } else {
        default_modes()
    };
    let algo = pick_algorithm(ctx);
    let grid = SweepGrid {
        algorithms: vec![algo.clone()],
        machines: ctx.cfg.machines.clone(),
        modes: modes.clone(),
        // Single-fleet scenario: run on the config's base fleet, like
        // every other single-fleet path (the hetero scenario is the
        // one that sweeps the fleet axis).
        fleets: ctx.base_fleet_axis(),
        // Single-workload scenario too: the base workload (the
        // workloads scenario is the one that sweeps the objective).
        workloads: vec![ctx.base_workload()],
        data: Vec::new(),
        events: String::new(),
        seeds: 1,
        base_seed: ctx.cfg.seed,
        run: ctx.run_config(),
    };
    let traces = ctx.run_grid(&grid)?;

    // A target every comparison shares: the config's if it is broadly
    // reachable, otherwise relaxed to what ~three quarters of the
    // cells achieved (SGD on a short iteration budget may never see
    // the paper's 1e-4).
    let mut eps = ctx.cfg.target_subopt;
    let reached = traces.iter().filter(|t| t.time_to(eps).is_some()).count();
    if reached * 2 < traces.len() {
        let finals: Vec<f64> = traces
            .iter()
            .map(|t| t.final_subopt().max(1e-12))
            .collect();
        eps = stats::percentile(&finals, 75.0) * 1.2;
        println!(
            "  (target {:.0e} unreachable for most cells; comparing at {eps:.2e})",
            ctx.cfg.target_subopt
        );
    }

    let mut table = Table::new(&[
        "machines",
        "barrier",
        "mean_iter_time",
        "iters_to_target",
        "time_to_target",
        "final_subopt",
    ]);
    let mut series = Vec::new();
    let mut best: Option<(BarrierMode, usize, f64)> = None;
    let mut best_bsp: Option<(usize, f64)> = None;
    for &mode in &modes {
        let mut pts = Vec::new();
        for &m in &ctx.cfg.machines {
            let Some(trace) = find_trace(&traces, &algo, m, mode) else {
                continue;
            };
            let tt = trace.time_to(eps);
            table.push(vec![
                m as f64,
                mode.csv_id(),
                trace.mean_iter_time(),
                trace.iters_to(eps).map(|i| i as f64).unwrap_or(f64::NAN),
                tt.unwrap_or(f64::NAN),
                trace.final_subopt(),
            ]);
            if let Some(t) = tt {
                pts.push((m as f64, t));
                if best.as_ref().map(|b| t < b.2).unwrap_or(true) {
                    best = Some((mode, m, t));
                }
                if mode.is_bsp() && best_bsp.as_ref().map(|b| t < b.1).unwrap_or(true) {
                    best_bsp = Some((m, t));
                }
            }
        }
        if !pts.is_empty() {
            series.push(Series::new(mode.as_str(), pts));
        }
    }
    ctx.write_csv("ssp_barrier_modes.csv", &table)?;
    if !series.is_empty() {
        ctx.show(
            &format!("SSP scenario: seconds to {eps:.1e} vs machines ({algo}, log y)"),
            series,
            true,
            "machines",
        );
    }

    let summary = match (best, best_bsp) {
        (Some((mode, m, t)), Some((m_bsp, t_bsp))) => format!(
            "ssp: {algo} to {eps:.1e} — best bsp {t_bsp:.2}s @ m={m_bsp}; \
             best overall {t:.2}s @ (m={m}, {mode}); speedup ×{:.2}{}",
            t_bsp / t,
            if mode.is_bsp() { " (barrier relaxation did not pay)" } else { "" }
        ),
        (Some((mode, m, t)), None) => format!(
            "ssp: {algo} to {eps:.1e} — only relaxed modes reached it; \
             best {t:.2}s @ (m={m}, {mode})"
        ),
        _ => format!("ssp: {algo} reached {eps:.1e} under no (m, mode) — grid too small"),
    };
    println!("{summary}\n");
    Ok(summary)
}

fn find_trace<'a>(
    traces: &'a [Trace],
    algo: &str,
    machines: usize,
    mode: BarrierMode,
) -> Option<&'a Trace> {
    traces
        .iter()
        .find(|t| t.algorithm == algo && t.machines == machines && t.barrier_mode == mode)
}

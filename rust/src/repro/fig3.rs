//! Figures 3 & 7: the fitted Hemingway model vs true CoCoA+
//! convergence — (a) in iterations for every m, (b) in time via the
//! combined Ernest+Hemingway model. Fig 7 is the appendix zoom to the
//! first 100 iterations.

use super::common::{iter_series, time_series, ReproContext};
use crate::advisor::CombinedModel;
use crate::hemingway_model::{points_from_traces, ConvergenceModel, FeatureLibrary};
use crate::optim::TraceSet;
use crate::util::asciiplot::Series;
use crate::util::csv::Table;
use crate::util::stats;

/// Shared sweep + model fit used by fig 3, 4, 7, 8 (one CoCoA+ sweep).
pub struct SweepFit {
    pub traces: TraceSet,
    pub model: ConvergenceModel,
}

pub fn sweep_and_fit(ctx: &ReproContext) -> crate::Result<SweepFit> {
    let traces = ctx.run_sweep("cocoa+")?;
    let pts = points_from_traces(&traces.traces);
    let model = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), ctx.cfg.seed)?;
    crate::log_info!(
        "convergence model: R²={:.4} on {} points; selected {:?}",
        model.train_r2,
        model.n_train,
        model.selected_features()
    );
    Ok(SweepFit { traces, model })
}

pub fn fig3a(ctx: &ReproContext, fit: &SweepFit, cap: Option<usize>) -> crate::Result<String> {
    let tag = if cap.is_some() { "7(a-d)" } else { "3(a)" };
    println!("== Figure {tag}: model fit vs true CoCoA+ convergence (iterations) ==");
    let mut table = Table::new(&["machines", "iter", "true_subopt", "model_subopt"]);
    let mut series = Vec::new();
    let mut lnerrs = Vec::new();
    for trace in &fit.traces.traces {
        let m = trace.machines as f64;
        let truth = iter_series(trace, cap);
        let pred: Vec<(f64, f64)> = truth
            .iter()
            .map(|&(i, _)| (i, fit.model.predict(i, m)))
            .collect();
        for (&(i, t), &(_, p)) in truth.iter().zip(&pred) {
            table.push(vec![m, i, t, p]);
            lnerrs.push((t.ln() - p.ln()).abs());
        }
        if trace.machines == 1 || trace.machines == 16 || trace.machines == 128 {
            series.push(Series::new(format!("true m={}", trace.machines), truth));
            series.push(Series::new(format!("fit m={}", trace.machines), pred));
        }
    }
    let name = if cap.is_some() {
        "fig7_model_fit_100iters.csv"
    } else {
        "fig3a_model_fit.csv"
    };
    ctx.write_csv(name, &table)?;
    ctx.show(
        &format!("Fig {tag}: true vs fitted g(i,m) (log y)"),
        series,
        true,
        "iteration",
    );
    let mean_lnerr = stats::mean(&lnerrs);
    let summary = format!(
        "fig{}: mean |Δln subopt| = {:.3} over {} points (fit R²={:.4}) — trends captured: {}",
        if cap.is_some() { "7" } else { "3a" },
        mean_lnerr,
        lnerrs.len(),
        fit.model.train_r2,
        if mean_lnerr < 1.0 { "yes" } else { "NO" }
    );
    println!("{summary}\n");
    Ok(summary)
}

pub fn fig3b(ctx: &ReproContext, fit: &SweepFit) -> crate::Result<String> {
    println!("== Figure 3(b): combined Ernest+Hemingway model vs time ==");
    let ernest = ctx.fit_ernest("cocoa+")?;
    let combined = CombinedModel::new(ernest, fit.model.clone(), ctx.problem.data.n as f64);
    let mut table = Table::new(&["machines", "time", "true_subopt", "model_subopt"]);
    let mut series = Vec::new();
    let mut lnerrs = Vec::new();
    for trace in &fit.traces.traces {
        let m = trace.machines;
        let truth = time_series(trace, None);
        let pred: Vec<(f64, f64)> = truth
            .iter()
            .map(|&(t, _)| (t, combined.subopt_at_time(t, m)))
            .collect();
        for (&(t, tr), &(_, p)) in truth.iter().zip(&pred) {
            table.push(vec![m as f64, t, tr, p]);
            if tr > 0.0 && p > 0.0 {
                lnerrs.push((tr.ln() - p.ln()).abs());
            }
        }
        if m == 1 || m == 16 || m == 128 {
            series.push(Series::new(format!("true m={m}"), truth));
            series.push(Series::new(format!("h(t,{m})"), pred));
        }
    }
    ctx.write_csv("fig3b_combined_model.csv", &table)?;
    ctx.show(
        "Fig 3(b): true vs combined h(t,m) (log y)",
        series,
        true,
        "simulated seconds",
    );
    let mean_lnerr = stats::mean(&lnerrs);
    let summary = format!(
        "fig3b: mean |Δln subopt| = {mean_lnerr:.3} in the time domain — combined model {}",
        if mean_lnerr < 1.2 { "captures trends" } else { "FAILS" }
    );
    println!("{summary}\n");
    Ok(summary)
}

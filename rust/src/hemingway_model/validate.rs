//! Validation protocols from the paper's §4: leave-one-m-out
//! cross-validation (Fig 4/8) and forward prediction with a trailing
//! window (Fig 5/9: +k iterations; Fig 6/10: +Δt seconds, composed
//! with the Ernest model).

use super::features::FeatureLibrary;
use super::model::{points_from_traces, ConvPoint, ConvergenceModel};
use crate::ernest::ErnestModel;
use crate::optim::trace::Trace;

/// Leave-one-m-out: fit on every trace except `held_out` machines,
/// return (model, the held-out trace's predictions as (iter, truth, pred)).
pub fn loo_m(
    traces: &[Trace],
    held_out: usize,
    seed: u64,
) -> crate::Result<(ConvergenceModel, Vec<(f64, f64, f64)>)> {
    let train: Vec<Trace> = traces
        .iter()
        .filter(|t| t.machines != held_out)
        .cloned()
        .collect();
    crate::ensure!(!train.is_empty(), "no training traces left");
    let test = traces
        .iter()
        .find(|t| t.machines == held_out)
        .ok_or_else(|| crate::err!("no trace with m={held_out}"))?;

    let model = ConvergenceModel::fit(
        &points_from_traces(&train),
        FeatureLibrary::standard(),
        seed,
    )?;
    let preds = test
        .records
        .iter()
        .filter(|r| r.iter >= 1 && r.subopt > 0.0)
        .map(|r| {
            (
                r.iter as f64,
                r.subopt,
                model.predict(r.iter as f64, held_out as f64),
            )
        })
        .collect();
    Ok((model, preds))
}

/// Forward prediction: at each iteration `t ≥ window`, fit on the
/// window `[t − window, t)` of this single trace and predict `t + k`.
/// Returns (target_iter, truth, prediction) triples.
pub fn forward_iterations(
    trace: &Trace,
    window: usize,
    ahead: usize,
    seed: u64,
) -> crate::Result<Vec<(f64, f64, f64)>> {
    let usable: Vec<&crate::optim::trace::Record> = trace
        .records
        .iter()
        .filter(|r| r.iter >= 1 && r.subopt > 0.0)
        .collect();
    let mut out = Vec::new();
    let lib = FeatureLibrary::iteration_only();
    let m = trace.machines as f64;

    for t in window..usable.len() {
        let target = t + ahead - 1;
        if target >= usable.len() {
            break;
        }
        let pts: Vec<ConvPoint> = usable[t - window..t]
            .iter()
            .map(|r| ConvPoint {
                iter: r.iter as f64,
                machines: m,
                subopt: r.subopt,
            })
            .collect();
        if pts.len() < 12 {
            continue;
        }
        let model = ConvergenceModel::fit(&pts, lib.clone(), seed)?;
        let tr = usable[target];
        out.push((
            tr.iter as f64,
            tr.subopt,
            model.predict(tr.iter as f64, m),
        ));
    }
    Ok(out)
}

/// Forward prediction in *time* (Fig 6/10): fit on the window ending
/// at simulated time `now`, compose with Ernest to map `now + delta`
/// to an iteration index, and predict there. Returns
/// (target_time, truth_subopt_at_nearest_record, prediction).
pub fn forward_time(
    trace: &Trace,
    ernest: &ErnestModel,
    input_size: f64,
    window: usize,
    delta_t: f64,
    seed: u64,
) -> crate::Result<Vec<(f64, f64, f64)>> {
    let usable: Vec<&crate::optim::trace::Record> = trace
        .records
        .iter()
        .filter(|r| r.iter >= 1 && r.subopt > 0.0)
        .collect();
    let mut out = Vec::new();
    let lib = FeatureLibrary::iteration_only();
    let m = trace.machines as f64;
    let f_m = ernest.predict(trace.machines, input_size);
    crate::ensure!(f_m > 0.0, "Ernest predicts non-positive iteration time");

    for t in window..usable.len() {
        let now = usable[t - 1].sim_time;
        let target_time = now + delta_t;
        // Predicted iteration index at the target time.
        let target_iter = target_time / f_m;
        // Ground truth: the record whose sim_time is closest.
        let Some(truth_rec) = usable
            .iter()
            .min_by(|a, b| {
                (a.sim_time - target_time)
                    .abs()
                    .partial_cmp(&(b.sim_time - target_time).abs())
                    .unwrap()
            })
        else {
            break;
        };
        if (truth_rec.sim_time - target_time).abs() > f_m {
            continue; // no ground-truth record near the target time
        }
        let pts: Vec<ConvPoint> = usable[t - window..t]
            .iter()
            .map(|r| ConvPoint {
                iter: r.iter as f64,
                machines: m,
                subopt: r.subopt,
            })
            .collect();
        if pts.len() < 12 {
            continue;
        }
        let model = ConvergenceModel::fit(&pts, lib.clone(), seed)?;
        out.push((target_time, truth_rec.subopt, model.predict(target_iter, m)));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::trace::{Record, Trace};

    fn synth_trace(m: usize, iters: usize, c0: f64, time_per_iter: f64) -> Trace {
        let mut t = Trace::new("cocoa+", m, 0.1);
        for i in 0..=iters {
            let subopt = 0.5 * (-c0 * i as f64 / m as f64).exp();
            t.push(Record {
                iter: i,
                sim_time: i as f64 * time_per_iter,
                primal: 0.1 + subopt,
                dual: f64::NAN,
                subopt,
            });
        }
        t
    }

    fn sweep() -> Vec<Trace> {
        [1usize, 2, 4, 8, 16, 32, 64, 128]
            .iter()
            .map(|&m| synth_trace(m, 100, 0.6, 0.1))
            .collect()
    }

    #[test]
    fn loo_m_128_tracks_truth() {
        let traces = sweep();
        let (_, preds) = loo_m(&traces, 128, 1).unwrap();
        assert!(preds.len() > 50);
        for (i, truth, pred) in &preds {
            assert!(
                (truth.ln() - pred.ln()).abs() < 0.3,
                "i={i}: {truth} vs {pred}"
            );
        }
    }

    #[test]
    fn loo_m_errors_for_missing_m() {
        let traces = sweep();
        assert!(loo_m(&traces, 7, 1).is_err());
    }

    #[test]
    fn forward_one_ahead_is_accurate() {
        let trace = synth_trace(16, 120, 0.6, 0.1);
        let preds = forward_iterations(&trace, 50, 1, 1).unwrap();
        assert!(preds.len() > 30, "{}", preds.len());
        for (i, truth, pred) in &preds {
            assert!(
                (truth.ln() - pred.ln()).abs() < 0.1,
                "i={i}: {truth} vs {pred}"
            );
        }
    }

    #[test]
    fn forward_ten_ahead_worse_but_sane() {
        let trace = synth_trace(16, 120, 0.6, 0.1);
        let p1 = forward_iterations(&trace, 50, 1, 1).unwrap();
        let p10 = forward_iterations(&trace, 50, 10, 1).unwrap();
        let mean_err = |ps: &[(f64, f64, f64)]| {
            ps.iter()
                .map(|(_, t, p)| (t.ln() - p.ln()).abs())
                .sum::<f64>()
                / ps.len() as f64
        };
        assert!(mean_err(&p10) < 0.5);
        assert!(mean_err(&p1) <= mean_err(&p10) + 1e-9);
    }

    #[test]
    fn forward_time_composes_with_ernest() {
        use crate::ernest::Observation;
        let tpi = 0.1;
        let trace = synth_trace(16, 150, 0.6, tpi);
        // Ernest trained on configs consistent with constant tpi.
        let obs: Vec<Observation> = [1usize, 2, 4, 8, 16]
            .iter()
            .map(|&m| Observation {
                machines: m,
                size: 8192.0,
                time: tpi,
            })
            .collect();
        let ernest = ErnestModel::fit(&obs).unwrap();
        let preds = forward_time(&trace, &ernest, 8192.0, 50, 5.0 * tpi, 1).unwrap();
        assert!(preds.len() > 20);
        for (t, truth, pred) in &preds {
            assert!(
                (truth.ln() - pred.ln()).abs() < 0.2,
                "t={t}: {truth} vs {pred}"
            );
        }
    }
}

//! The Hemingway convergence model g(i, m): a LassoCV fit of
//! `log(P(i, m) − P*)` over the feature library (paper §3.2.2, §4).

use super::features::FeatureLibrary;
use super::lasso::{lasso_cv, LassoFit};
use crate::linalg::Matrix;
use crate::optim::trace::Trace;
use crate::util::json::Json;
use crate::util::stats;

/// One training point for the convergence model.
#[derive(Debug, Clone, Copy)]
pub struct ConvPoint {
    pub iter: f64,
    pub machines: f64,
    pub subopt: f64,
}

/// Extract usable (i ≥ 1, subopt > 0) points from traces.
pub fn points_from_traces(traces: &[Trace]) -> Vec<ConvPoint> {
    let mut pts = Vec::new();
    for t in traces {
        for r in &t.records {
            if r.iter >= 1 && r.subopt > 0.0 && r.subopt.is_finite() {
                pts.push(ConvPoint {
                    iter: r.iter as f64,
                    machines: t.machines as f64,
                    subopt: r.subopt,
                });
            }
        }
    }
    pts
}

/// The fitted convergence model.
#[derive(Debug, Clone)]
pub struct ConvergenceModel {
    pub library: FeatureLibrary,
    pub fit: LassoFit,
    /// Diagnostics on training data.
    pub train_r2: f64,
    pub n_train: usize,
    /// Prediction floor: ¼ of the smallest suboptimality observed in
    /// training. A black-box fit of log-suboptimality happily
    /// extrapolates exponential decay far past any evidence; clamping
    /// keeps the advisor from promising 1e-8 when training runs
    /// stopped at 1e-4 (the paper's §6 "training time" caveat).
    pub floor: f64,
}

impl ConvergenceModel {
    /// Fit `log(subopt) ~ φ(i, m)` with LassoCV (paper's procedure).
    pub fn fit(points: &[ConvPoint], library: FeatureLibrary, seed: u64) -> crate::Result<ConvergenceModel> {
        crate::ensure!(
            points.len() >= 12,
            "need ≥12 convergence observations, got {}",
            points.len()
        );
        let x = Matrix::from_fn(points.len(), library.len(), |i, j| {
            library.row(points[i].iter, points[i].machines)[j]
        });
        let y: Vec<f64> = points.iter().map(|p| p.subopt.ln()).collect();
        let cv = lasso_cv(&x, &y, 40, 5, seed)?;
        let pred = cv.fit.predict(&x);
        let train_r2 = stats::r_squared(&y, &pred);
        let floor = points
            .iter()
            .map(|p| p.subopt)
            .fold(f64::INFINITY, f64::min)
            * 0.25;
        Ok(ConvergenceModel {
            library,
            fit: cv.fit,
            train_r2,
            n_train: points.len(),
            floor,
        })
    }

    /// Predicted log-suboptimality at (i, m).
    pub fn predict_ln(&self, iter: f64, machines: f64) -> f64 {
        self.fit.predict_row(&self.library.row(iter, machines))
    }

    /// Predicted suboptimality at (i, m), clamped to the training floor.
    pub fn predict(&self, iter: f64, machines: f64) -> f64 {
        self.predict_ln(iter, machines).exp().max(self.floor)
    }

    /// Smallest iteration count with predicted suboptimality ≤ eps
    /// (None if not reached within `cap`).
    pub fn iters_to(&self, eps: f64, machines: f64, cap: usize) -> Option<usize> {
        // The model is smooth; scan coarse then refine (predictions are
        // not guaranteed monotone, so scan rather than bisect).
        let mut prev_ok: Option<usize> = None;
        for i in 1..=cap {
            if self.predict(i as f64, machines) <= eps {
                prev_ok = Some(i);
                break;
            }
        }
        prev_ok
    }

    /// Serialize for a model artifact (`util::json`): feature names
    /// (the library's durable identity), Lasso coefficients, and the
    /// prediction floor. Floats round-trip bit-identically; any
    /// non-finite value is refused here rather than silently becoming
    /// JSON `null` (which would produce an artifact that never loads).
    pub fn to_json(&self) -> crate::Result<Json> {
        crate::ensure!(
            self.floor.is_finite(),
            "refusing to persist a non-finite prediction floor ({})",
            self.floor
        );
        let coeffs_finite = self.fit.coef.iter().all(|c| c.is_finite())
            && self.fit.intercept.is_finite()
            && self.fit.alpha.is_finite()
            && self.train_r2.is_finite();
        crate::ensure!(
            coeffs_finite,
            "refusing to persist a non-finite convergence model (intercept {}, alpha {})",
            self.fit.intercept,
            self.fit.alpha
        );
        Ok(Json::object(vec![
            (
                "features",
                Json::array(self.library.names().iter().map(|n| Json::str(*n))),
            ),
            ("coef", Json::array(self.fit.coef.iter().map(|&c| Json::num(c)))),
            ("intercept", Json::num(self.fit.intercept)),
            ("alpha", Json::num(self.fit.alpha)),
            ("iterations", Json::num(self.fit.iterations as f64)),
            ("train_r2", Json::num(self.train_r2)),
            ("n_train", Json::num(self.n_train as f64)),
            ("floor", Json::num(self.floor)),
        ]))
    }

    /// Rebuild a fitted model from its artifact form.
    pub fn from_json(doc: &Json) -> crate::Result<ConvergenceModel> {
        let names: Vec<&str> = doc
            .req_array("features")?
            .iter()
            .map(|v| {
                v.as_str()
                    .ok_or_else(|| crate::err!("convergence feature name is not a string"))
            })
            .collect::<crate::Result<_>>()?;
        let library = FeatureLibrary::from_names(&names)?;
        let coef: Vec<f64> = doc
            .req_array("coef")?
            .iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| crate::err!("convergence coefficient is not a number"))
            })
            .collect::<crate::Result<_>>()?;
        crate::ensure!(
            coef.len() == library.len(),
            "artifact has {} coefficients for {} features",
            coef.len(),
            library.len()
        );
        let floor = doc.req_f64("floor")?;
        crate::ensure!(floor.is_finite(), "model artifact has a non-finite floor");
        Ok(ConvergenceModel {
            library,
            fit: LassoFit {
                coef,
                intercept: doc.req_f64("intercept")?,
                alpha: doc.req_f64("alpha")?,
                iterations: doc.req_usize("iterations")?,
            },
            train_r2: doc.req_f64("train_r2")?,
            n_train: doc.req_usize("n_train")?,
            floor,
        })
    }

    /// Named non-zero coefficients (interpretability / ablation logs).
    pub fn selected_features(&self) -> Vec<(&'static str, f64)> {
        self.library
            .names()
            .iter()
            .zip(&self.fit.coef)
            .filter(|(_, &c)| c != 0.0)
            .map(|(n, &c)| (*n, c))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic CoCoA-like decay: subopt = c1 · exp(−c0 · i / m).
    fn synthetic_points(ms: &[f64], iters: usize, c0: f64, c1: f64) -> Vec<ConvPoint> {
        let mut pts = Vec::new();
        for &m in ms {
            for i in 1..=iters {
                pts.push(ConvPoint {
                    iter: i as f64,
                    machines: m,
                    subopt: c1 * (-c0 * i as f64 / m).exp(),
                });
            }
        }
        pts
    }

    #[test]
    fn fits_theory_form_exactly() {
        let pts = synthetic_points(&[1.0, 2.0, 4.0, 8.0, 16.0], 60, 0.8, 0.5);
        let model = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), 1).unwrap();
        assert!(model.train_r2 > 0.999, "r2={}", model.train_r2);
        // Must be dominated by the theory feature i/m.
        let sel = model.selected_features();
        assert!(
            sel.iter().any(|(n, _)| *n == "i/m"),
            "selected: {sel:?}"
        );
        // Pointwise accuracy.
        for &(i, m) in &[(10.0, 4.0), (50.0, 16.0), (30.0, 2.0)] {
            let truth = 0.5 * (-0.8f64 * i / m).exp();
            let pred = model.predict(i, m);
            assert!(
                (pred.ln() - truth.ln()).abs() < 0.05,
                "i={i} m={m}: {pred} vs {truth}"
            );
        }
    }

    #[test]
    fn extrapolates_to_unseen_m() {
        // Leave-one-m-out (paper §4.1): train on m ≤ 64, predict m=128.
        let pts = synthetic_points(&[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0], 80, 0.8, 0.5);
        let model = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), 2).unwrap();
        for i in [10.0, 40.0, 80.0] {
            let truth = 0.5 * (-0.8f64 * i / 128.0).exp();
            let pred = model.predict(i, 128.0);
            assert!(
                (pred.ln() - truth.ln()).abs() < 0.25,
                "i={i}: pred {} vs truth {}",
                pred.ln(),
                truth.ln()
            );
        }
    }

    #[test]
    fn iters_to_inverts_prediction() {
        let pts = synthetic_points(&[1.0, 4.0, 16.0], 100, 0.5, 1.0);
        let model = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), 3).unwrap();
        let eps = 1e-3;
        let i4 = model.iters_to(eps, 4.0, 1000).unwrap();
        // Truth: i = m/c0 · ln(c1/eps) = 4/0.5 · ln(1000) ≈ 55.
        assert!((40..=75).contains(&i4), "i4={i4}");
        // More machines ⇒ more iterations.
        let i16 = model.iters_to(eps, 16.0, 5000).unwrap();
        assert!(i16 > i4);
        // Unreachable target within cap.
        assert_eq!(model.iters_to(1e-30, 4.0, 10), None);
    }

    #[test]
    fn json_roundtrip_is_bit_identical() {
        let pts = synthetic_points(&[1.0, 2.0, 4.0, 8.0, 16.0], 60, 0.8, 0.5);
        let model = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), 1).unwrap();
        assert!(model.floor.is_finite() && model.floor > 0.0);
        let text = model.to_json().unwrap().to_pretty();
        let back = ConvergenceModel::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.library.names(), model.library.names());
        for (a, b) in model.fit.coef.iter().zip(&back.fit.coef) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(model.fit.intercept.to_bits(), back.fit.intercept.to_bits());
        assert_eq!(model.floor.to_bits(), back.floor.to_bits());
        assert_eq!(model.selected_features(), back.selected_features());
        for &(i, m) in &[(1.0, 1.0), (10.0, 4.0), (50.0, 16.0), (500.0, 128.0)] {
            assert_eq!(model.predict(i, m).to_bits(), back.predict(i, m).to_bits());
            assert_eq!(
                model.predict_ln(i, m).to_bits(),
                back.predict_ln(i, m).to_bits()
            );
        }
    }

    #[test]
    fn rejects_too_few_points() {
        let pts = synthetic_points(&[1.0], 5, 0.5, 1.0);
        assert!(ConvergenceModel::fit(&pts, FeatureLibrary::standard(), 1).is_err());
    }

    #[test]
    fn points_from_traces_filters_invalid() {
        use crate::optim::trace::{Record, Trace};
        let mut t = Trace::new("cocoa", 4, 0.5);
        t.push(Record { iter: 0, sim_time: 0.0, primal: 1.0, dual: 0.0, subopt: 0.5 });
        t.push(Record { iter: 1, sim_time: 0.1, primal: 0.9, dual: 0.0, subopt: 0.4 });
        t.push(Record { iter: 2, sim_time: 0.2, primal: 0.5, dual: 0.0, subopt: 0.0 });
        t.push(Record { iter: 3, sim_time: 0.3, primal: 0.5, dual: 0.0, subopt: -1e-9 });
        let pts = points_from_traces(&[t]);
        assert_eq!(pts.len(), 1); // only iter=1 qualifies
        assert_eq!(pts[0].machines, 4.0);
    }
}

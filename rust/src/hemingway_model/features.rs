//! The feature library φ_j(i, m) for the convergence model.
//!
//! Paper §3.2.2: "A range of fractional, polynomial, and logarithmic
//! terms were used as the features of our model", with
//! `log(P(i,m) − P*) = Σ λ_j φ_j(i, m)` fitted by LassoCV. The library
//! here is deliberately generous — Lasso owns the selection. The
//! theory-motivated member is `i/m` (CoCoA's upper bound
//! `(1 − c0/m)^i c1` has log ≈ −c0·i/m + log c1).

/// One named feature.
#[derive(Clone)]
pub struct Feature {
    pub name: &'static str,
    pub f: fn(f64, f64) -> f64,
}

impl std::fmt::Debug for Feature {
    fn fmt(&self, fm: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(fm, "Feature({})", self.name)
    }
}

/// An ordered feature set.
#[derive(Debug, Clone)]
pub struct FeatureLibrary {
    pub features: Vec<Feature>,
}

impl FeatureLibrary {
    /// The default library used by all paper-reproduction fits.
    pub fn standard() -> FeatureLibrary {
        FeatureLibrary {
            features: vec![
                Feature { name: "i", f: |i, _| i },
                Feature { name: "i/m", f: |i, m| i / m },
                Feature { name: "i/m^2", f: |i, m| i / (m * m) },
                Feature { name: "i/sqrt(m)", f: |i, m| i / m.sqrt() },
                Feature { name: "i*log(m+1)", f: |i, m| i * (m + 1.0).ln() },
                Feature { name: "log(i+1)", f: |i, _| (i + 1.0).ln() },
                Feature { name: "sqrt(i)", f: |i, _| i.sqrt() },
                Feature { name: "1/i", f: |i, _| 1.0 / i.max(1.0) },
                Feature { name: "m", f: |_, m| m },
                Feature { name: "log(m+1)", f: |_, m| (m + 1.0).ln() },
                Feature { name: "1/m", f: |_, m| 1.0 / m },
                Feature {
                    name: "log(i+1)*log(m+1)",
                    f: |i, m| (i + 1.0).ln() * (m + 1.0).ln(),
                },
                Feature { name: "sqrt(i)/m", f: |i, m| i.sqrt() / m },
            ],
        }
    }

    /// A reduced iteration-only library (forward prediction on a
    /// single-m window, where m-features are constant and useless).
    pub fn iteration_only() -> FeatureLibrary {
        FeatureLibrary {
            features: vec![
                Feature { name: "i", f: |i, _| i },
                Feature { name: "log(i+1)", f: |i, _| (i + 1.0).ln() },
                Feature { name: "sqrt(i)", f: |i, _| i.sqrt() },
                Feature { name: "1/i", f: |i, _| 1.0 / i.max(1.0) },
            ],
        }
    }

    /// Rebuild a library from persisted feature names (model
    /// artifacts). Every built-in library draws from the standard
    /// catalog, so names are the durable identity of a feature — the
    /// function pointers themselves cannot be serialized.
    pub fn from_names(names: &[&str]) -> crate::Result<FeatureLibrary> {
        let catalog = FeatureLibrary::standard();
        let mut features = Vec::with_capacity(names.len());
        for name in names {
            let f = catalog
                .features
                .iter()
                .find(|f| f.name == *name)
                .ok_or_else(|| {
                    crate::err!("unknown convergence feature '{name}' in model artifact")
                })?;
            features.push(f.clone());
        }
        Ok(FeatureLibrary { features })
    }

    pub fn len(&self) -> usize {
        self.features.len()
    }

    pub fn is_empty(&self) -> bool {
        self.features.is_empty()
    }

    /// Evaluate the full feature row at (i, m).
    pub fn row(&self, iter: f64, machines: f64) -> Vec<f64> {
        self.features.iter().map(|f| (f.f)(iter, machines)).collect()
    }

    pub fn names(&self) -> Vec<&'static str> {
        self.features.iter().map(|f| f.name).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_finite_over_the_domain() {
        let lib = FeatureLibrary::standard();
        for &i in &[1.0, 2.0, 10.0, 500.0] {
            for &m in &[1.0, 2.0, 128.0] {
                let row = lib.row(i, m);
                assert_eq!(row.len(), lib.len());
                assert!(row.iter().all(|v| v.is_finite()), "i={i} m={m} {row:?}");
            }
        }
    }

    #[test]
    fn theory_feature_behaves() {
        let lib = FeatureLibrary::standard();
        let idx = lib.names().iter().position(|&n| n == "i/m").unwrap();
        let r1 = lib.row(100.0, 1.0);
        let r16 = lib.row(100.0, 16.0);
        assert_eq!(r1[idx], 100.0);
        assert_eq!(r16[idx], 6.25);
    }

    #[test]
    fn from_names_roundtrips_every_builtin_library() {
        for lib in [FeatureLibrary::standard(), FeatureLibrary::iteration_only()] {
            let names = lib.names();
            let back = FeatureLibrary::from_names(&names).unwrap();
            assert_eq!(back.names(), names);
            // Same functions, not just the same labels.
            assert_eq!(back.row(17.0, 8.0), lib.row(17.0, 8.0));
        }
        assert!(FeatureLibrary::from_names(&["i", "not-a-feature"]).is_err());
    }

    #[test]
    fn names_unique() {
        let lib = FeatureLibrary::standard();
        let mut names = lib.names();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), lib.len());
    }
}

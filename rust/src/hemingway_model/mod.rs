//! Hemingway's convergence model `g(i, m)` (paper §3.2.2 and §4):
//! feature library, from-scratch Lasso/LassoCV, model fitting on
//! log-suboptimality, and the paper's validation protocols.

pub mod features;
pub mod lasso;
pub mod model;
pub mod validate;

pub use features::FeatureLibrary;
pub use lasso::{lasso, lasso_cv, LassoCvFit, LassoFit};
pub use model::{points_from_traces, ConvPoint, ConvergenceModel};
pub use validate::{forward_iterations, forward_time, loo_m};

//! Lasso via cyclic coordinate descent, plus k-fold cross-validated
//! penalty selection — the from-scratch equivalent of scikit-learn's
//! `LassoCV` the paper fits its convergence model with.
//!
//! Objective (sklearn convention):
//!   (1/2n)‖y − Xβ − β0‖² + α‖β‖₁
//! Features are standardized internally (zero mean, unit variance) and
//! coefficients mapped back, so callers pass raw feature matrices.

use crate::linalg::Matrix;
use crate::util::rng::Pcg32;
use crate::util::stats;

/// A fitted Lasso model (coefficients in the original feature scale).
#[derive(Debug, Clone)]
pub struct LassoFit {
    pub coef: Vec<f64>,
    pub intercept: f64,
    pub alpha: f64,
    pub iterations: usize,
}

impl LassoFit {
    pub fn predict_row(&self, row: &[f64]) -> f64 {
        self.intercept + row.iter().zip(&self.coef).map(|(x, b)| x * b).sum::<f64>()
    }

    pub fn predict(&self, x: &Matrix) -> Vec<f64> {
        (0..x.rows).map(|i| self.predict_row(x.row(i))).collect()
    }

    /// Indices of non-zero coefficients (the selected features).
    pub fn support(&self) -> Vec<usize> {
        self.coef
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0.0)
            .map(|(j, _)| j)
            .collect()
    }
}

struct Standardized {
    xs: Matrix,
    y_c: Vec<f64>,
    x_mean: Vec<f64>,
    x_scale: Vec<f64>,
    y_mean: f64,
}

fn standardize(x: &Matrix, y: &[f64]) -> Standardized {
    let n = x.rows;
    let p = x.cols;
    let mut x_mean = vec![0.0; p];
    let mut x_scale = vec![0.0; p];
    for j in 0..p {
        let col: Vec<f64> = (0..n).map(|i| x[(i, j)]).collect();
        x_mean[j] = stats::mean(&col);
        let var: f64 =
            col.iter().map(|v| (v - x_mean[j]) * (v - x_mean[j])).sum::<f64>() / n as f64;
        x_scale[j] = var.sqrt().max(1e-12);
    }
    let y_mean = stats::mean(y);
    let xs = Matrix::from_fn(n, p, |i, j| (x[(i, j)] - x_mean[j]) / x_scale[j]);
    let y_c: Vec<f64> = y.iter().map(|v| v - y_mean).collect();
    Standardized {
        xs,
        y_c,
        x_mean,
        x_scale,
        y_mean,
    }
}

fn soft_threshold(z: f64, g: f64) -> f64 {
    if z > g {
        z - g
    } else if z < -g {
        z + g
    } else {
        0.0
    }
}

/// Fit Lasso at a single penalty `alpha` (standardized internally).
pub fn lasso(x: &Matrix, y: &[f64], alpha: f64) -> crate::Result<LassoFit> {
    lasso_warm(x, y, alpha, None)
}

fn lasso_warm(
    x: &Matrix,
    y: &[f64],
    alpha: f64,
    warm: Option<&[f64]>,
) -> crate::Result<LassoFit> {
    crate::ensure!(x.rows == y.len(), "X/y length mismatch");
    crate::ensure!(x.rows > 1, "need more than one row");
    let n = x.rows;
    let p = x.cols;
    let s = standardize(x, y);

    // Per-column squared norms / n (all ≈1 after standardization, but
    // keep exact values for near-constant columns).
    let col_nsq: Vec<f64> = (0..p)
        .map(|j| (0..n).map(|i| s.xs[(i, j)] * s.xs[(i, j)]).sum::<f64>() / n as f64)
        .collect();

    let mut beta: Vec<f64> = warm.map(|w| w.to_vec()).unwrap_or_else(|| vec![0.0; p]);
    // Residual r = y_c − Xs β.
    let mut r = s.y_c.clone();
    if warm.is_some() {
        for i in 0..n {
            let pred: f64 = (0..p).map(|j| s.xs[(i, j)] * beta[j]).sum();
            r[i] -= pred;
        }
    }

    let max_iter = 1000;
    let tol = 1e-7;
    let mut iterations = 0;
    for it in 0..max_iter {
        let mut max_delta = 0.0f64;
        for j in 0..p {
            if col_nsq[j] < 1e-10 {
                continue; // constant column: unidentifiable, leave 0
            }
            // ρ_j = (1/n) x_jᵀ(r + x_j β_j)
            let mut rho = 0.0;
            for i in 0..n {
                rho += s.xs[(i, j)] * r[i];
            }
            rho = rho / n as f64 + col_nsq[j] * beta[j];
            let b_new = soft_threshold(rho, alpha) / col_nsq[j];
            let delta = b_new - beta[j];
            if delta != 0.0 {
                for i in 0..n {
                    r[i] -= delta * s.xs[(i, j)];
                }
                beta[j] = b_new;
                max_delta = max_delta.max(delta.abs());
            }
        }
        iterations = it + 1;
        if max_delta < tol {
            break;
        }
    }

    // Map back to original scale.
    let coef: Vec<f64> = beta
        .iter()
        .zip(&s.x_scale)
        .map(|(b, sc)| b / sc)
        .collect();
    let intercept =
        s.y_mean - coef.iter().zip(&s.x_mean).map(|(c, m)| c * m).sum::<f64>();
    Ok(LassoFit {
        coef,
        intercept,
        alpha,
        iterations,
    })
}

/// The α where all coefficients are zero (path start).
pub fn alpha_max(x: &Matrix, y: &[f64]) -> f64 {
    let s = standardize(x, y);
    let n = x.rows as f64;
    (0..x.cols)
        .map(|j| {
            ((0..x.rows).map(|i| s.xs[(i, j)] * s.y_c[i]).sum::<f64>() / n).abs()
        })
        .fold(0.0, f64::max)
}

/// Result of cross-validated penalty selection.
#[derive(Debug, Clone)]
pub struct LassoCvFit {
    pub fit: LassoFit,
    /// The λ path searched.
    pub alphas: Vec<f64>,
    /// Mean CV MSE per path point.
    pub cv_mse: Vec<f64>,
}

/// K-fold cross-validated Lasso (the paper's LassoCV).
pub fn lasso_cv(
    x: &Matrix,
    y: &[f64],
    n_alphas: usize,
    folds: usize,
    seed: u64,
) -> crate::Result<LassoCvFit> {
    crate::ensure!(folds >= 2, "need ≥2 folds");
    crate::ensure!(x.rows >= folds * 2, "too few rows for {folds}-fold CV");
    let a_max = alpha_max(x, y).max(1e-12);
    let a_min = a_max * 1e-4;
    let alphas: Vec<f64> = (0..n_alphas)
        .map(|k| {
            let t = k as f64 / (n_alphas - 1).max(1) as f64;
            a_max * (a_min / a_max).powf(t)
        })
        .collect();

    // Fold assignment (shuffled).
    let mut rng = Pcg32::new(seed, 777);
    let perm = rng.permutation(x.rows);
    let fold_of: Vec<usize> = {
        let mut f = vec![0usize; x.rows];
        for (pos, &i) in perm.iter().enumerate() {
            f[i] = pos % folds;
        }
        f
    };

    let mut cv_mse = vec![0.0f64; alphas.len()];
    for fold in 0..folds {
        let train_idx: Vec<usize> = (0..x.rows).filter(|&i| fold_of[i] != fold).collect();
        let test_idx: Vec<usize> = (0..x.rows).filter(|&i| fold_of[i] == fold).collect();
        let xtr = x.select_rows(&train_idx);
        let ytr: Vec<f64> = train_idx.iter().map(|&i| y[i]).collect();
        let xte = x.select_rows(&test_idx);
        let yte: Vec<f64> = test_idx.iter().map(|&i| y[i]).collect();

        // Warm-start down the path.
        let mut warm: Option<Vec<f64>> = None;
        for (k, &a) in alphas.iter().enumerate() {
            let fit = lasso_warm(&xtr, &ytr, a, warm.as_deref())?;
            // Reuse the *standardized* coefficients for warm starting:
            // re-standardize by multiplying back. Simpler: warm-start
            // in original scale is invalid, so re-derive standardized
            // betas from the returned fit.
            let s = standardize(&xtr, &ytr);
            warm = Some(
                fit.coef
                    .iter()
                    .zip(&s.x_scale)
                    .map(|(c, sc)| c * sc)
                    .collect(),
            );
            let pred = fit.predict(&xte);
            cv_mse[k] += stats::rmse(&yte, &pred).powi(2) * yte.len() as f64;
        }
    }
    for v in cv_mse.iter_mut() {
        *v /= x.rows as f64;
    }

    let best = cv_mse
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(k, _)| k)
        .unwrap();
    let fit = lasso(x, y, alphas[best])?;
    Ok(LassoCvFit {
        fit,
        alphas,
        cv_mse,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{forall, Gen};

    fn planted(n: usize, p: usize, truth: &[f64], noise: f64, seed: u64) -> (Matrix, Vec<f64>) {
        let mut rng = Pcg32::new(seed, 31);
        let x = Matrix::from_fn(n, p, |_, _| rng.normal());
        let y: Vec<f64> = (0..n)
            .map(|i| {
                x.row(i)
                    .iter()
                    .zip(truth)
                    .map(|(xv, t)| xv * t)
                    .sum::<f64>()
                    + 2.5
                    + noise * rng.normal()
            })
            .collect();
        (x, y)
    }

    #[test]
    fn zero_alpha_recovers_ols() {
        let truth = [1.5, -2.0, 0.7];
        let (x, y) = planted(200, 3, &truth, 0.0, 1);
        let fit = lasso(&x, &y, 1e-10).unwrap();
        for (c, t) in fit.coef.iter().zip(&truth) {
            assert!((c - t).abs() < 1e-4, "{:?}", fit.coef);
        }
        assert!((fit.intercept - 2.5).abs() < 1e-4);
    }

    #[test]
    fn alpha_max_kills_all_coefficients() {
        let (x, y) = planted(100, 4, &[1.0, 0.0, -1.0, 0.5], 0.1, 2);
        let am = alpha_max(&x, &y);
        let fit = lasso(&x, &y, am * 1.0001).unwrap();
        assert!(fit.coef.iter().all(|&c| c == 0.0), "{:?}", fit.coef);
        // And slightly below, at least one enters.
        let fit2 = lasso(&x, &y, am * 0.99).unwrap();
        assert!(fit2.support().len() >= 1);
    }

    #[test]
    fn selects_sparse_support() {
        // 8 features, only 2 relevant.
        let truth = [3.0, 0.0, 0.0, -2.0, 0.0, 0.0, 0.0, 0.0];
        let (x, y) = planted(300, 8, &truth, 0.05, 3);
        let cv = lasso_cv(&x, &y, 30, 5, 7).unwrap();
        let support = cv.fit.support();
        assert!(support.contains(&0) && support.contains(&3), "{support:?}");
        // CV-min λ famously overselects a little; what matters is that
        // spurious coefficients are tiny relative to the real ones.
        for (j, &c) in cv.fit.coef.iter().enumerate() {
            if truth[j] == 0.0 {
                assert!(c.abs() < 0.1, "spurious coef {j} = {c}");
            } else {
                assert!((c - truth[j]).abs() < 0.1, "coef {j} = {c}");
            }
        }
        // Good predictions.
        let pred = cv.fit.predict(&x);
        assert!(stats::r_squared(&y, &pred) > 0.99);
    }

    #[test]
    fn cv_path_is_well_formed() {
        let (x, y) = planted(120, 5, &[1.0, -1.0, 0.0, 0.0, 0.5], 0.1, 4);
        let cv = lasso_cv(&x, &y, 20, 4, 1).unwrap();
        assert_eq!(cv.alphas.len(), 20);
        assert_eq!(cv.cv_mse.len(), 20);
        // Path is decreasing in α.
        for w in cv.alphas.windows(2) {
            assert!(w[0] > w[1]);
        }
        assert!(cv.cv_mse.iter().all(|v| v.is_finite() && *v >= 0.0));
    }

    #[test]
    fn handles_constant_columns() {
        let mut rng = Pcg32::seeded(5);
        let x = Matrix::from_fn(50, 3, |_, j| if j == 1 { 4.2 } else { rng.normal() });
        let y: Vec<f64> = (0..50).map(|i| 2.0 * x[(i, 0)] + 1.0).collect();
        let fit = lasso(&x, &y, 1e-6).unwrap();
        assert_eq!(fit.coef[1], 0.0);
        assert!((fit.coef[0] - 2.0).abs() < 1e-3);
    }

    #[test]
    fn prediction_error_shrinks_with_more_data() {
        let truth = [1.0, -0.5, 2.0, 0.0, 0.0];
        let err = |n: usize| {
            let (x, y) = planted(n, 5, &truth, 0.5, 6);
            let fit = lasso(&x, &y, 0.01).unwrap();
            truth
                .iter()
                .zip(&fit.coef)
                .map(|(t, c)| (t - c) * (t - c))
                .sum::<f64>()
        };
        assert!(err(1000) < err(30));
    }

    #[test]
    fn lasso_objective_never_worse_than_zero_vector() {
        forall(
            "lasso beats the null model",
            15,
            |g: &mut Gen| {
                let n = g.usize_in(20, 80);
                let p = g.usize_in(1, 6);
                let mut rng = Pcg32::seeded(g.rng().next_u64());
                let x = Matrix::from_fn(n, p, |_, _| rng.normal());
                let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let alpha = g.f64_in(1e-4, 0.5);
                ((n, p), (x, y, alpha))
            },
            |_, (x, y, alpha)| {
                let n = x.rows as f64;
                let fit = lasso(x, y, *alpha).unwrap();
                // The solver penalizes *standardized* betas:
                // β_std_j = coef_j · std(x_j).
                let l1_std: f64 = (0..x.cols)
                    .map(|j| {
                        let col: Vec<f64> = (0..x.rows).map(|i| x[(i, j)]).collect();
                        let mu = stats::mean(&col);
                        let sd = (col.iter().map(|v| (v - mu) * (v - mu)).sum::<f64>()
                            / n)
                            .sqrt();
                        (fit.coef[j] * sd).abs()
                    })
                    .sum();
                let obj = |pred: &[f64], l1: f64| {
                    let mse: f64 =
                        y.iter().zip(pred).map(|(a, b)| (a - b) * (a - b)).sum::<f64>();
                    mse / (2.0 * n) + alpha * l1
                };
                let fit_obj = obj(&fit.predict(x), l1_std);
                // Null model: β=0, intercept = mean(y).
                let ym = stats::mean(y);
                let null_obj = obj(&vec![ym; x.rows], 0.0);
                fit_obj <= null_obj + 1e-9
            },
        );
    }
}

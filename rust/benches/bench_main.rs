//! Benchmark harness (`cargo bench`). The offline registry has no
//! criterion, so this is a self-contained harness: warmup + timed
//! iterations, reporting mean / p50 / p95 per benchmark.
//!
//! Groups (one per paper table/figure + the §Perf hot paths):
//!   kernels     — per-call cost of each AOT kernel, HLO vs native
//!   iteration   — end-to-end BSP iteration cost (Fig 1a's x-axis)
//!   sweep       — the sweep engine: thread scaling + cache hits
//!   sweep_store — sharded v5 store vs flat v4: probe/load/codec, plus
//!                 streaming + aggregation throughput (BENCH_sweep.json)
//!   data        — dense vs CSR kernels at the scenario densities and
//!                 the skewed partitioner's overhead (BENCH_data.json)
//!   models      — NNLS / Lasso / LassoCV / convergence-fit cost
//!   advisor     — query latency over a fitted model set
//!   calib       — the calibration microbenchmark suite + profile fit
//!                 (BENCH_calib.json; every snapshot carries the host
//!                 fingerprint that produced it)
//!
//! HLO groups run only when the PJRT engine is available (`pjrt`
//! feature + artifacts); everything else is native and always runs.
//!
//! Filter with `cargo bench -- <substring>`.

use std::time::Instant;

use hemingway::cluster::{BspSim, HardwareProfile};
use hemingway::config::ExperimentConfig;
use hemingway::data::synth::mnist_like;
use hemingway::ernest::{ErnestModel, Observation};
use hemingway::hemingway_model::{
    lasso_cv, points_from_traces, ConvergenceModel, FeatureLibrary,
};
use hemingway::linalg::{nnls, Matrix};
use hemingway::optim::{
    by_name, run, Backend, HloBackend, NativeBackend, Problem, Record, RunConfig, Trace,
};
use hemingway::runtime::{default_artifact_dir, Engine};
use hemingway::sweep::{
    CellScratch, CellSpec, StreamAggregator, SweepEngine, SweepGrid, TraceCache,
};
use hemingway::util::rng::{Lcg32, Pcg32};
use hemingway::util::stats;
use hemingway::util::threadpool::default_threads;

struct Bench {
    filter: String,
    results: Vec<(String, f64, f64, f64, u64)>,
}

impl Bench {
    fn new() -> Bench {
        // `cargo bench -- foo` passes "foo" through.
        let filter = std::env::args()
            .skip(1)
            .find(|a| !a.starts_with('-'))
            .unwrap_or_default();
        Bench {
            filter,
            results: Vec::new(),
        }
    }

    /// Time `f` with automatic iteration count targeting ~0.8 s.
    fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        if !self.filter.is_empty() && !name.contains(&self.filter) {
            return;
        }
        // Warmup + calibration.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64().max(1e-9);
        let iters = ((0.8 / once) as u64).clamp(3, 2000);
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        let mean = stats::mean(&samples);
        let p50 = stats::median(&samples);
        let p95 = stats::percentile(&samples, 95.0);
        println!(
            "{name:<52} mean {:>12} p50 {:>12} p95 {:>12} (n={iters})",
            fmt_t(mean),
            fmt_t(p50),
            fmt_t(p95)
        );
        self.results.push((name.to_string(), mean, p50, p95, iters));
    }
}

/// Bench snapshots (`BENCH_*.json`) are checked in at the repo root,
/// not the crate dir — resolve against the manifest dir so `cargo
/// bench` lands them in the same place regardless of cwd.
fn bench_out(name: &str) -> std::path::PathBuf {
    std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/..")).join(name)
}

fn fmt_t(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

fn main() -> hemingway::Result<()> {
    let mut b = Bench::new();
    println!("== hemingway bench harness (filter: '{}') ==\n", b.filter);
    // Every BENCH_*.json snapshot is stamped with the host that
    // produced it — cross-host comparisons of checked-in numbers are
    // apples-to-oranges otherwise.
    let host = hemingway::calib::HostFingerprint::detect();
    println!("host: {}\n", host.summary());

    let engine = match Engine::new(&default_artifact_dir()) {
        Ok(e) => {
            e.warmup()?;
            println!(
                "engine warmed up ({} executables)\n",
                e.manifest().artifacts.len()
            );
            Some(e)
        }
        Err(e) => {
            println!("PJRT engine unavailable ({e});\nrunning native-only benches\n");
            None
        }
    };

    // ---------------- kernels: HLO vs native per-call ----------------
    let mut rng = Pcg32::seeded(1);
    for &n_loc in &[64usize, 512, 4096] {
        let d = 128;
        let x: Vec<f32> = (0..n_loc * d).map(|_| rng.normal() as f32 * 0.3).collect();
        let y: Vec<f32> = (0..n_loc)
            .map(|_| if rng.uniform() < 0.5 { 1.0 } else { -1.0 })
            .collect();
        let mask = vec![1.0f32; n_loc];
        let alpha = vec![0.0f32; n_loc];
        let w = vec![0.01f32; d];
        let seed = Lcg32::for_epoch(1, 0, 0).state;
        let lambda_n = 0.01 * n_loc as f32;

        if let Some(engine) = &engine {
            b.bench(&format!("kernels/cocoa_local/hlo/n{n_loc}"), || {
                engine
                    .cocoa_local(&x, &y, &mask, &alpha, &w, lambda_n, 1.0, seed)
                    .unwrap();
            });
        }
        b.bench(&format!("kernels/cocoa_local/native/n{n_loc}"), || {
            hemingway::optim::native::sdca_epoch(
                &x, &y, &mask, &alpha, &w, lambda_n as f64, 1.0, seed, n_loc,
            );
        });
        if let Some(engine) = &engine {
            b.bench(&format!("kernels/grad/hlo/n{n_loc}"), || {
                engine.grad(&x, &y, &mask, &w).unwrap();
            });
        }
        b.bench(&format!("kernels/grad/native/n{n_loc}"), || {
            hemingway::optim::native::hinge_stats(&x, &y, &mask, &w);
        });
        if let Some(engine) = &engine {
            b.bench(&format!("kernels/local_sgd/hlo/n{n_loc}"), || {
                engine.local_sgd(&x, &y, &mask, &w, 0.01, 10.0, seed).unwrap();
            });

            // Buffer-cached path (§Perf optimization A): partition tensors
            // device-resident, only alpha/w/scalars travel per call.
            let ds = hemingway::data::Dataset::new(x.clone(), y.clone(), n_loc, d);
            let part = ds.partition(1)?.remove(0);
            b.bench(&format!("kernels/cocoa_local/hlo-cached/n{n_loc}"), || {
                engine
                    .cocoa_local_part(&part, &alpha, &w, lambda_n, 1.0, seed)
                    .unwrap();
            });
            b.bench(&format!("kernels/grad/hlo-cached/n{n_loc}"), || {
                engine.grad_part(&part, &part.mask, &w).unwrap();
            });
        }
    }
    println!();

    // ---------------- end-to-end BSP iteration (Fig 1a) ----------------
    let cfg = ExperimentConfig::default();
    let data = mnist_like(&cfg.synth());
    let problem = Problem::new(data, cfg.lambda);
    for &m in &[1usize, 16, 128] {
        let mut backends: Vec<(&str, Box<dyn Backend + '_>)> = Vec::new();
        if let Some(engine) = &engine {
            backends.push(("hlo", Box::new(HloBackend::new(engine))));
        }
        backends.push(("native", Box::new(NativeBackend)));
        for (bname, backend) in &backends {
            let mut algo = by_name("cocoa+", &problem, m, 1).unwrap();
            let mut i = 0usize;
            b.bench(&format!("iteration/cocoa+/{bname}/m{m}"), || {
                algo.step(backend.as_ref(), i).unwrap();
                i += 1;
            });
        }
    }
    // Objective evaluation (runs once per iteration in the driver).
    {
        let w = vec![0.01f32; problem.data.d];
        b.bench("iteration/objective_eval/native", || {
            problem.primal(&w);
        });
    }
    println!();

    // ---------------- workloads: one sweep cell per objective ----------------
    // The objective-generic hot paths: a full driver run (one sweep
    // cell) per workload on a small problem, plus each workload's
    // primal evaluation. Means land in BENCH_workloads.json so the
    // perf trajectory tracks the generic kernels per objective.
    let mut workload_means: Vec<(hemingway::optim::Objective, f64, f64)> = Vec::new();
    {
        use hemingway::data::synth::dataset_for;
        use hemingway::optim::Objective;
        let small = ExperimentConfig {
            n: 1024,
            d: 32,
            ..Default::default()
        };
        for obj in Objective::ALL {
            let sdata = dataset_for(obj, &small.synth());
            let sproblem = Problem::with_objective(sdata, small.lambda, obj);
            let (sp_star, _, _) = sproblem.reference_solve(1e-5, 200);
            let cell_run = RunConfig {
                max_iters: 15,
                target_subopt: -1.0,
                time_budget: None,
            };
            b.bench(&format!("workloads/cell/cocoa+/{obj}"), || {
                let mut algo = by_name("cocoa+", &sproblem, 4, 1).unwrap();
                let mut sim = BspSim::new(HardwareProfile::local48(), 7);
                run(
                    algo.as_mut(),
                    &NativeBackend,
                    &sproblem,
                    &mut sim,
                    sp_star,
                    &cell_run,
                )
                .unwrap();
            });
            let w = vec![0.01f32; sproblem.data.d];
            b.bench(&format!("workloads/primal/{obj}"), || {
                sproblem.primal(&w);
            });
            let find_mean = |name: &str| {
                b.results
                    .iter()
                    .find(|(n, ..)| n == name)
                    .map(|(_, mean, ..)| *mean)
                    .unwrap_or(f64::NAN)
            };
            workload_means.push((
                obj,
                find_mean(&format!("workloads/cell/cocoa+/{obj}")),
                find_mean(&format!("workloads/primal/{obj}")),
            ));
        }
    }
    // Emit the per-workload perf snapshot (skipped under a filter that
    // excluded the workload benches — no stale file overwrites).
    if workload_means.iter().any(|(_, cell, _)| cell.is_finite()) {
        use hemingway::util::json::Json;
        let entries: Vec<(String, Json)> = workload_means
            .iter()
            .map(|(obj, cell, primal)| {
                (
                    obj.as_str().to_string(),
                    Json::object(vec![
                        ("cell_seconds_mean", Json::num(*cell)),
                        ("primal_seconds_mean", Json::num(*primal)),
                    ]),
                )
            })
            .collect();
        let doc = Json::object(vec![
            ("bench", Json::str("workloads")),
            ("host", host.to_json()),
            ("algorithm", Json::str("cocoa+")),
            ("machines", Json::num(4.0)),
            ("workloads", Json::Object(entries)),
        ]);
        let path = bench_out("BENCH_workloads.json");
        std::fs::write(&path, doc.to_pretty())?;
        println!("wrote {}", path.display());
    }
    println!();

    // ---------------- data: dense vs CSR kernels + partition skew ----------------
    // The data-axis hot paths: one local SDCA epoch and one
    // loss/gradient scan, dense store vs CSR at the sweep's scenario
    // densities, plus the partitioner's skewed-placement overhead.
    // Means land in BENCH_data.json so the sparse speedup and the
    // skew cost track over time.
    {
        use hemingway::data::synth::{dataset_for, dataset_for_scenario, SynthConfig};
        use hemingway::data::{Csr, DataScenario};
        use hemingway::optim::{native, Objective};

        let dcfg = SynthConfig {
            n: 4096,
            d: 128,
            seed: 11,
            ..Default::default()
        };
        let dense = dataset_for(Objective::Hinge, &dcfg);
        let dpart = dense.partition(1)?.remove(0);
        let alpha = vec![0.0f32; dpart.n_loc];
        let w = vec![0.01f32; dpart.d];
        let weights = vec![1.0f32 / dpart.n_loc as f32; dpart.n_loc];
        let lambda_n = 0.01 * dpart.n_loc as f64;
        let kseed = Lcg32::for_epoch(3, 0, 0).state;
        b.bench("data/sdca_epoch/dense", || {
            native::sdca_epoch_obj(
                Objective::Hinge,
                &dpart.x,
                &dpart.y,
                &dpart.mask,
                &alpha,
                &w,
                lambda_n,
                1.0,
                kseed,
                dpart.n_loc,
            );
        });
        b.bench("data/loss_stats/dense", || {
            native::loss_stats(Objective::Hinge, &dpart.x, &dpart.y, &weights, &w);
        });
        // CSR at density 1.0 stores every entry (zeros included): the
        // pure store-format overhead, same flops as the dense scan.
        let full = Csr::from_dense_full(&dpart.x, dpart.n_loc, dpart.d);
        b.bench("data/sdca_epoch/csr/density1", || {
            native::sdca_epoch_csr(
                Objective::Hinge,
                &full,
                &dpart.y,
                &dpart.mask,
                &alpha,
                &w,
                lambda_n,
                1.0,
                kseed,
                dpart.n_loc,
            );
        });
        // Real sparse stores: the scenario generator's CSR datasets.
        for &density in &[0.1f64, 0.01] {
            let scenario = DataScenario::parse(&format!("sparse:{density}"))?;
            let sds = dataset_for_scenario(Objective::Hinge, &scenario, &dcfg);
            let spart = sds.partition(1)?.remove(0);
            let csr = spart.csr.as_ref().expect("scenario partition is CSR-stored");
            b.bench(&format!("data/sdca_epoch/csr/density{density}"), || {
                native::sdca_epoch_csr(
                    Objective::Hinge,
                    csr,
                    &spart.y,
                    &spart.mask,
                    &alpha,
                    &w,
                    lambda_n,
                    1.0,
                    kseed,
                    spart.n_loc,
                );
            });
            if density == 0.01 {
                b.bench(&format!("data/loss_stats/csr/density{density}"), || {
                    native::loss_stats_csr(Objective::Hinge, csr, &spart.y, &weights, &w);
                });
            }
        }
        // Partitioner cost: the historical IID split vs the skewed
        // placement (label-sorted keys + ramped sizes) at m=16.
        let pcfg = SynthConfig {
            n: 8192,
            d: 32,
            seed: 12,
            ..Default::default()
        };
        let pds = dataset_for(Objective::Hinge, &pcfg);
        let skewed = pds.clone().with_skew(0.6, 7);
        b.bench("data/partition/m16/iid", || {
            pds.partition(16).unwrap();
        });
        b.bench("data/partition/m16/skew0.6", || {
            skewed.partition(16).unwrap();
        });

        // Emit the data-axis perf snapshot (skipped under a filter that
        // excluded these benches — no stale file overwrites).
        let mean = |name: &str| {
            b.results
                .iter()
                .find(|(n, ..)| n == name)
                .map(|(_, m, ..)| *m)
                .unwrap_or(f64::NAN)
        };
        let dense_epoch = mean("data/sdca_epoch/dense");
        let csr001 = mean("data/sdca_epoch/csr/density0.01");
        if dense_epoch.is_finite() && csr001.is_finite() {
            use hemingway::util::json::Json;
            let doc = Json::object(vec![
                ("bench", Json::str("data")),
                ("host", host.to_json()),
                ("n", Json::num(dcfg.n as f64)),
                ("d", Json::num(dcfg.d as f64)),
                ("sdca_epoch_dense_s", Json::num(dense_epoch)),
                ("sdca_epoch_csr_density1_s", Json::num(mean("data/sdca_epoch/csr/density1"))),
                ("sdca_epoch_csr_density0.1_s", Json::num(mean("data/sdca_epoch/csr/density0.1"))),
                ("sdca_epoch_csr_density0.01_s", Json::num(csr001)),
                ("csr_speedup_at_density0.01", Json::num(dense_epoch / csr001)),
                ("loss_stats_dense_s", Json::num(mean("data/loss_stats/dense"))),
                ("loss_stats_csr_density0.01_s", Json::num(mean("data/loss_stats/csr/density0.01"))),
                ("partition_iid_m16_s", Json::num(mean("data/partition/m16/iid"))),
                ("partition_skew0.6_m16_s", Json::num(mean("data/partition/m16/skew0.6"))),
                (
                    "partition_skew_overhead",
                    Json::num(mean("data/partition/m16/skew0.6") / mean("data/partition/m16/iid")),
                ),
            ]);
            let path = bench_out("BENCH_data.json");
            std::fs::write(&path, doc.to_pretty())?;
            println!("wrote {}", path.display());
        }
    }
    println!();

    // ---------------- sweep engine: thread scaling + cache ----------------
    {
        let small = ExperimentConfig {
            n: 1024,
            d: 32,
            machines: vec![1, 2, 4, 8],
            max_iters: 30,
            ..Default::default()
        };
        let sdata = mnist_like(&small.synth());
        let sproblem = Problem::new(sdata, small.lambda);
        let (sp_star, _, _) = sproblem.reference_solve(1e-6, 300);
        let grid = SweepGrid {
            algorithms: vec!["cocoa+".into()],
            machines: small.machines.clone(),
            modes: vec![hemingway::cluster::BarrierMode::Bsp],
            fleets: Vec::new(),
            workloads: Vec::new(),
            data: Vec::new(),
            events: String::new(),
            seeds: 2,
            base_seed: small.seed,
            run: RunConfig {
                max_iters: 30,
                target_subopt: -1.0,
                time_budget: None,
            },
        };
        let cells = grid.cells();
        let runner = |cell: &CellSpec, _scratch: &mut CellScratch| -> hemingway::Result<Trace> {
            let mut algo = by_name(&cell.algorithm, &sproblem, cell.machines, cell.seed as u32)?;
            let mut sim = BspSim::new(
                HardwareProfile::local48(),
                cell.seed ^ cell.machines as u64,
            );
            run(
                algo.as_mut(),
                &NativeBackend,
                &sproblem,
                &mut sim,
                sp_star,
                &grid.run,
            )
        };
        // Cold cache: measures actual fan-out; 1 thread vs all cores.
        for &threads in &[1usize, default_threads()] {
            b.bench(&format!("sweep/8cells/cold/threads{threads}"), || {
                let eng = SweepEngine::new(threads, TraceCache::in_memory());
                eng.run_cells("bench", &cells, &runner).unwrap();
            });
        }
        // Warm cache: every cell hits, measuring pure cache overhead.
        let warm = SweepEngine::new(default_threads(), TraceCache::in_memory());
        warm.run_cells("bench", &cells, &runner).unwrap();
        b.bench("sweep/8cells/cache_hit", || {
            warm.run_cells("bench", &cells, &runner).unwrap();
        });
    }
    println!();

    // ---------------- sweep store: sharded v5 vs flat v4 ----------------
    // The on-disk trace store at scale: a 10k-entry grid probed and
    // loaded through the sharded binary layout, against an emulated
    // pre-v5 flat text layout (full read + parse per lookup — what the
    // cache did before sharding). Means land in BENCH_sweep.json.
    {
        use hemingway::sweep::cache::{hash_key, parse_trace, serialize_trace};
        use hemingway::sweep::store::{decode_trace_v5, encode_trace, encode_trace_into, Probe};
        use hemingway::sweep::ShardedStore;

        const STORE_CELLS: usize = 10_000;
        let mut trace = Trace::new("cocoa+", 16, 0.01);
        for i in 0..8 {
            trace.push(Record {
                iter: i,
                sim_time: i as f64 * 0.1,
                primal: 0.5 / (i + 1) as f64,
                dual: f64::NAN,
                subopt: 0.5 / (i + 1) as f64,
            });
        }

        let base =
            std::env::temp_dir().join(format!("hemingway_bench_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let flat_dir = base.join("flat_v4");
        std::fs::create_dir_all(&flat_dir)?;
        let store = ShardedStore::open(&base.join("sharded"));
        let key_of = |i: usize| format!("bench-store|algo=cocoa+|m=16|cell={i}");
        let mut buf = Vec::new();
        for i in 0..STORE_CELLS {
            let key = key_of(i);
            store.store(&key, &trace, &mut buf);
            std::fs::write(
                flat_dir.join(format!("{:016x}.trace", hash_key(&key))),
                serialize_trace(&key, &trace),
            )?;
        }

        // The pre-shard lookup: read the whole flat file, parse every
        // record, compare the key.
        let flat_load = |key: &str| -> Option<Trace> {
            let path = flat_dir.join(format!("{:016x}.trace", hash_key(key)));
            let text = std::fs::read_to_string(path).ok()?;
            let (k, t) = parse_trace(&text).ok()?;
            (k == key).then_some(t)
        };

        let mut i = 0usize;
        b.bench("sweep_store/probe_hit/sharded_v5", || {
            i += 1;
            assert!(!matches!(store.probe(&key_of(i % STORE_CELLS)), Probe::Miss));
        });
        let mut i = 0usize;
        b.bench("sweep_store/probe_hit/flat_v4", || {
            i += 1;
            assert!(flat_load(&key_of(i % STORE_CELLS)).is_some());
        });
        let mut i = 0usize;
        b.bench("sweep_store/probe_miss/sharded_v5", || {
            i += 1;
            assert!(matches!(store.probe(&key_of(STORE_CELLS + i)), Probe::Miss));
        });
        let mut i = 0usize;
        b.bench("sweep_store/probe_miss/flat_v4", || {
            i += 1;
            assert!(flat_load(&key_of(STORE_CELLS + i)).is_none());
        });
        let mut i = 0usize;
        b.bench("sweep_store/load_hit/sharded_v5", || {
            i += 1;
            assert!(store.load(&key_of(i % STORE_CELLS)).is_some());
        });

        // Codec cost alone, no filesystem: binary v5 vs text v4.
        let v5_bytes = encode_trace("k", &trace);
        let v4_text = serialize_trace("k", &trace);
        b.bench("sweep_store/decode/v5", || {
            decode_trace_v5(&v5_bytes).unwrap();
        });
        b.bench("sweep_store/decode/v4_text", || {
            parse_trace(&v4_text).unwrap();
        });
        let mut enc = Vec::new();
        b.bench("sweep_store/encode/v5_into", || {
            encode_trace_into("k", &trace, &mut enc);
        });

        // Streaming executor + aggregator throughput on a synthetic
        // 512-cell grid (runner cost ~ trace construction, so this
        // measures the engine's own overhead per cell).
        let sgrid = SweepGrid {
            algorithms: vec!["cocoa+".into()],
            machines: (1..=512).collect(),
            modes: vec![hemingway::cluster::BarrierMode::Bsp],
            fleets: Vec::new(),
            workloads: Vec::new(),
            data: Vec::new(),
            events: String::new(),
            seeds: 1,
            base_seed: 1,
            run: RunConfig::default(),
        };
        let scells = sgrid.cells();
        let synth = |cell: &CellSpec, _scratch: &mut CellScratch| -> hemingway::Result<Trace> {
            let mut t = Trace::new(cell.algorithm.clone(), cell.machines, 0.0);
            for i in 0..8 {
                t.push(Record {
                    iter: i,
                    sim_time: i as f64,
                    primal: 1.0,
                    dual: f64::NAN,
                    subopt: 1.0 / (i + 1) as f64,
                });
            }
            Ok(t)
        };
        b.bench("sweep_store/stream/512cells", || {
            let eng = SweepEngine::new(default_threads(), TraceCache::in_memory());
            let mut n = 0usize;
            eng.run_cells_stream("bench-stream", &scells, &synth, &mut |_, _| {
                n += 1;
                Ok(())
            })
            .unwrap();
            assert_eq!(n, scells.len());
        });
        let agg_input: Vec<Trace> = scells
            .iter()
            .map(|c| synth(c, &mut CellScratch::default()).unwrap())
            .collect();
        b.bench("sweep_store/aggregate/512traces", || {
            let mut acc = StreamAggregator::new(1e-4);
            for t in &agg_input {
                acc.push(t);
            }
            assert_eq!(acc.finish().len(), scells.len());
        });

        // Emit the store perf snapshot (skipped under a filter that
        // excluded these benches — no stale file overwrites).
        let mean = |name: &str| {
            b.results
                .iter()
                .find(|(n, ..)| n == name)
                .map(|(_, m, ..)| *m)
                .unwrap_or(f64::NAN)
        };
        let hit5 = mean("sweep_store/probe_hit/sharded_v5");
        let hit4 = mean("sweep_store/probe_hit/flat_v4");
        if hit5.is_finite() && hit4.is_finite() {
            use hemingway::util::json::Json;
            let miss5 = mean("sweep_store/probe_miss/sharded_v5");
            let miss4 = mean("sweep_store/probe_miss/flat_v4");
            let load5 = mean("sweep_store/load_hit/sharded_v5");
            let dec5 = mean("sweep_store/decode/v5");
            let dec4 = mean("sweep_store/decode/v4_text");
            let enc5 = mean("sweep_store/encode/v5_into");
            let stream = mean("sweep_store/stream/512cells");
            let agg = mean("sweep_store/aggregate/512traces");
            let doc = Json::object(vec![
                ("bench", Json::str("sweep_store")),
                ("host", host.to_json()),
                ("store_entries", Json::num(STORE_CELLS as f64)),
                ("probe_hit_sharded_v5_s", Json::num(hit5)),
                ("probe_hit_flat_v4_s", Json::num(hit4)),
                ("probe_hit_speedup_vs_flat_v4", Json::num(hit4 / hit5)),
                ("probe_miss_sharded_v5_s", Json::num(miss5)),
                ("probe_miss_flat_v4_s", Json::num(miss4)),
                ("load_hit_sharded_v5_s", Json::num(load5)),
                ("decode_v5_s", Json::num(dec5)),
                ("decode_v4_text_s", Json::num(dec4)),
                ("encode_v5_into_s", Json::num(enc5)),
                ("stream_cells_per_s", Json::num(scells.len() as f64 / stream)),
                ("aggregate_traces_per_s", Json::num(agg_input.len() as f64 / agg)),
            ]);
            let path = bench_out("BENCH_sweep.json");
            std::fs::write(&path, doc.to_pretty())?;
            println!("wrote {}", path.display());
        }
        let _ = std::fs::remove_dir_all(&base);
    }
    println!();

    // ---------------- model fitting ----------------
    {
        // NNLS on Ernest-shaped data.
        let ms = [1usize, 2, 4, 8, 16, 32, 64, 128];
        let a = Matrix::from_fn(ms.len() * 8, 4, |i, j| {
            ErnestModel::features(ms[i % ms.len()], 8192.0)[j]
        });
        let rhs: Vec<f64> = (0..a.rows).map(|i| 0.1 + 8192.0 * 4e-5 / ms[i % ms.len()] as f64).collect();
        b.bench("models/nnls/32x4", || {
            nnls(&a, &rhs).unwrap();
        });

        // LassoCV on a convergence-model-sized problem.
        let lib = FeatureLibrary::standard();
        let mut pts = Vec::new();
        for &m in &[1.0f64, 4.0, 16.0, 64.0] {
            for i in 1..=120 {
                pts.push((i as f64, m, 0.5 * (-0.7 * i as f64 / m).exp()));
            }
        }
        let x = Matrix::from_fn(pts.len(), lib.len(), |i, j| lib.row(pts[i].0, pts[i].1)[j]);
        let y: Vec<f64> = pts.iter().map(|p| p.2.ln()).collect();
        b.bench(&format!("models/lasso_cv/{}x{}", x.rows, x.cols), || {
            lasso_cv(&x, &y, 40, 5, 1).unwrap();
        });

        // Full convergence-model fit from real traces (m sweep of 3),
        // produced through the sweep engine like every other grid.
        let small = ExperimentConfig {
            n: 1024,
            machines: vec![1, 4, 16],
            max_iters: 100,
            ..Default::default()
        };
        let sdata = mnist_like(&small.synth());
        let sproblem = Problem::new(sdata, small.lambda);
        let (p_star, _, _) = sproblem.reference_solve(1e-7, 400);
        let grid = SweepGrid::single(
            "cocoa+",
            &small.machines,
            1,
            RunConfig {
                max_iters: 100,
                target_subopt: 1e-5,
                time_budget: None,
            },
        );
        let eng = SweepEngine::with_default_threads(TraceCache::in_memory());
        let models_runner =
            |cell: &CellSpec, _scratch: &mut CellScratch| -> hemingway::Result<Trace> {
                let mut algo =
                    by_name(&cell.algorithm, &sproblem, cell.machines, cell.seed as u32)?;
                let mut sim = BspSim::new(HardwareProfile::local48(), cell.machines as u64);
                run(
                    algo.as_mut(),
                    &NativeBackend,
                    &sproblem,
                    &mut sim,
                    p_star,
                    &grid.run,
                )
            };
        let traces = eng
            .run_cells("bench-models", &grid.cells(), &models_runner)
            .unwrap();
        let pts = points_from_traces(&traces);
        b.bench(&format!("models/convergence_fit/{}pts", pts.len()), || {
            ConvergenceModel::fit(&pts, FeatureLibrary::standard(), 1).unwrap();
        });

        // Ernest fit.
        let obs: Vec<Observation> = (0..40)
            .map(|i| {
                let m = ms[i % ms.len()];
                Observation {
                    machines: m,
                    size: 8192.0,
                    time: 0.1 + 0.33 / m as f64 + 0.01 * (m as f64).ln(),
                }
            })
            .collect();
        b.bench("models/ernest_fit/40obs", || {
            ErnestModel::fit(&obs).unwrap();
        });

        // ---------------- advisor ----------------
        let conv = ConvergenceModel::fit(&pts, FeatureLibrary::standard(), 1).unwrap();
        let ernest = ErnestModel::fit(&obs).unwrap();
        let mut registry =
            hemingway::advisor::ModelRegistry::new(vec![1, 2, 4, 8, 16, 32, 64, 128], 100_000);
        registry.insert(
            hemingway::advisor::ModelKey {
                algorithm: hemingway::advisor::AlgorithmId::CocoaPlus,
                context: "bench".to_string(),
            },
            hemingway::advisor::CombinedModel::new(ernest, conv, 8192.0),
        );
        b.bench("advisor/fastest_to_1e-3", || {
            registry.answer(&hemingway::advisor::Query::fastest_to(1e-3));
        });
        b.bench("advisor/best_at_30s", || {
            registry.answer(&hemingway::advisor::Query::best_at(30.0));
        });
        b.bench("advisor/serve_line", || {
            hemingway::advisor::handle_line(
                &registry,
                r#"{"query":"fastest_to","eps":1e-3,"max_machines":32}"#,
            );
        });

        // ---------------- serve: concurrent TCP front end ----------------
        // Whole load runs rather than closure timings, so gate on the
        // filter by hand; qps and percentiles come from the load
        // generator (client-side view, framing and sockets included).
        if b.filter.is_empty() || "serve/load".contains(&b.filter) {
            use hemingway::advisor::{AdvisorServer, FleetSpec, LoadConfig, ServerConfig};
            use hemingway::util::json::Json;
            // The load mix includes cheapest_to, which prices against
            // the model's base fleet — give the bench registry one.
            let mut serve_registry = registry.clone();
            let mut model = serve_registry
                .get(hemingway::advisor::AlgorithmId::CocoaPlus, "bench")
                .unwrap()
                .clone();
            model.base_fleet = "local48".into();
            serve_registry.insert(
                hemingway::advisor::ModelKey {
                    algorithm: hemingway::advisor::AlgorithmId::CocoaPlus,
                    context: "bench".to_string(),
                },
                model,
            );
            serve_registry.fleets = vec![FleetSpec::uniform(HardwareProfile::local48())];
            let workers = default_threads().clamp(2, 8);
            let server = AdvisorServer::bind(
                "127.0.0.1:0",
                serve_registry,
                ServerConfig {
                    workers,
                    queue_capacity: workers * 4,
                    reload: None,
                },
            )?;
            let addr = server.local_addr().to_string();
            let handle = std::thread::spawn(move || server.run());
            let queries = 4000;
            let single = hemingway::advisor::run_load(&LoadConfig::new(addr.clone(), 1, queries))?;
            let multi =
                hemingway::advisor::run_load(&LoadConfig::new(addr.clone(), workers, queries))?;
            hemingway::advisor::send_control(&addr, r#"{"query":"shutdown"}"#)?;
            handle.join().expect("server thread panicked")?;
            println!(
                "serve/load/1client             {:>10.0} qps   p50 {:>8.1}µs p99 {:>8.1}µs",
                single.qps, single.p50_us, single.p99_us
            );
            println!(
                "serve/load/{workers}clients            {:>10.0} qps   p50 {:>8.1}µs p99 {:>8.1}µs",
                multi.qps, multi.p50_us, multi.p99_us
            );
            let doc = Json::object(vec![
                ("bench", Json::str("serve")),
                ("host", host.to_json()),
                ("workers", Json::num(workers as f64)),
                ("queries_per_client", Json::num(queries as f64)),
                ("single_client", single.to_json()),
                ("multi_client", multi.to_json()),
                ("multi_vs_single_qps", Json::num(multi.qps / single.qps)),
            ]);
            let path = bench_out("BENCH_serve.json");
            std::fs::write(&path, doc.to_pretty())?;
            println!("wrote {}", path.display());
        }
    }

    // ---------------- calib: microbenchmark suite + profile fit ----------------
    // The calibration subsystem's own cost: one quick on-host suite
    // (real kernels, threadpool fan-out, loopback TCP) plus the NNLS
    // profile fit over its samples. Whole-suite runs, not closure
    // timings — gate on the filter by hand like serve/load. Residuals
    // and the fitted headline numbers land in BENCH_calib.json.
    if b.filter.is_empty() || "calib".contains(&b.filter) {
        use hemingway::util::json::Json;
        let t0 = Instant::now();
        let samples = hemingway::calib::run_suite(true)?;
        let suite_s = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let fit = hemingway::calib::fit_measured("bench-host", &samples)?;
        let fit_s = t0.elapsed().as_secs_f64();
        println!("calib/suite/quick                                    {:>12}", fmt_t(suite_s));
        println!("calib/fit                                            {:>12}", fmt_t(fit_s));
        let doc = Json::object(vec![
            ("bench", Json::str("calib")),
            ("host", host.to_json()),
            ("suite_quick_s", Json::num(suite_s)),
            ("fit_s", Json::num(fit_s)),
            ("compute_samples", Json::num(samples.compute.len() as f64)),
            ("sched_samples", Json::num(samples.sched.len() as f64)),
            ("net_samples", Json::num(samples.net.len() as f64)),
            ("compute_rmse_s", Json::num(fit.compute_rmse)),
            ("sched_rmse_s", Json::num(fit.sched_rmse)),
            ("net_rmse_s", Json::num(fit.net_rmse)),
            ("flops_per_sec", Json::num(fit.profile.flops_per_sec)),
            ("iteration_overhead_s", Json::num(fit.profile.iteration_overhead)),
            ("sched_per_machine_s", Json::num(fit.profile.sched_per_machine)),
            ("net_latency_s", Json::num(fit.profile.net_latency)),
            ("net_bandwidth_bps", Json::num(fit.profile.net_bandwidth)),
            ("noise_sigma", Json::num(fit.profile.noise_sigma)),
        ]);
        let path = bench_out("BENCH_calib.json");
        std::fs::write(&path, doc.to_pretty())?;
        println!("wrote {}", path.display());
    }
    println!();

    // ---------------- summary ----------------
    let find = |name: &str| {
        b.results
            .iter()
            .find(|(n, ..)| n == name)
            .map(|(_, mean, ..)| *mean)
    };
    if engine.is_some() {
        println!("\n== HLO-vs-native ratios (runtime dispatch overhead) ==");
        for n_loc in [64usize, 512, 4096] {
            if let (Some(h), Some(nv)) = (
                find(&format!("kernels/cocoa_local/hlo/n{n_loc}")),
                find(&format!("kernels/cocoa_local/native/n{n_loc}")),
            ) {
                println!("  cocoa_local n{n_loc}: hlo/native = {:.2}×", h / nv);
            }
        }
    }
    if let (Some(t1), Some(tn)) = (
        find("sweep/8cells/cold/threads1"),
        find(&format!("sweep/8cells/cold/threads{}", default_threads())),
    ) {
        println!(
            "\n== sweep scaling: {} threads = {:.2}× over serial ==",
            default_threads(),
            t1 / tn
        );
    }
    Ok(())
}

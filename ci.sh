#!/usr/bin/env bash
# Tier-1 gate: build, tests, formatting. Run from the repo root.
# HEMINGWAY_THREADS=1 pins the sweep engine's scheduling for
# reproducible logs; traces are byte-identical at any thread count.
set -euo pipefail
cd "$(dirname "$0")/rust"

export HEMINGWAY_THREADS="${HEMINGWAY_THREADS:-1}"

cargo build --release
# Stub-compile check: the real PJRT executor must keep building against
# the in-tree xla API stub so the feature gate can't rot.
cargo build --release --features pjrt
cargo test -q
# Barrier-mode, fleet and workload invariants (uniform-fleet ≡
# plain-profile bitwise, slower-fleet ⇒ ≥ elapsed, hinge ≡ the
# pre-workload-axis path bitwise, suboptimality ≥ 0 on every workload,
# cache v3-as-miss / v4 round trip) under an explicitly pinned
# quickcheck seed, so a property failure in CI names a seed that
# reproduces locally.
QUICKCHECK_SEED=20170211 cargo test -q --release --test barrier_props
QUICKCHECK_SEED=20170211 cargo test -q --release --test workload_props
# Data-axis invariants (dense scenario ≡ the historical path bitwise,
# density-1.0 CSR ≡ dense to 0 ULP through the full driver, skewed
# partitions cover every row exactly once, trace-store v7 byte round
# trip with legacy v5/v6 bytes decoding as implicit dense) under the
# same pinned seed.
QUICKCHECK_SEED=20170211 cargo test -q --release --test data_props
# Sweep-store invariants (interrupted sweep + torn manifest resumes to
# a bitwise-identical aggregate, v4 flat fixtures migrate-on-hit and
# serve bit-identically, header-only probe ≡ full parse at any key
# length) under the same pinned seed.
QUICKCHECK_SEED=20170211 cargo test -q --release --test sweep_store
# Concurrent-server invariants (N clients byte-identical to the pure
# core, hot reload under load never tears a response) under the same
# pinned seed for log comparability.
QUICKCHECK_SEED=20170211 cargo test -q --release --test advisor_server
# Elastic-execution invariants (no-event elastic ≡ static bitwise,
# checkpoint/restore resumes bit-identically under live events, m→m
# resize is a strict no-op, wire encoding byte-stable for every f32/f64
# bit pattern incl. NaN/-0.0/±∞) under the same pinned seed.
QUICKCHECK_SEED=20170211 cargo test -q --release --test elastic_props
# Calibration invariants (fitter recovers randomized ground-truth
# profiles from synthetic samples, artifacts round-trip bit-exactly
# while truncation/schema bumps fail loudly, a measured profile with a
# built-in's exact numbers drives a bitwise-identical sim) under the
# same pinned seed.
QUICKCHECK_SEED=20170211 cargo test -q --release --test calib_props
cargo fmt --check

# Advisor-service smoke: fit-on-miss once, then three JSON queries
# through one `serve` process, with typed (seconds vs suboptimality)
# responses.
tmp="$(mktemp -d)"
trap 'kill "${serve_pid:-}" 2>/dev/null || true; rm -rf "$tmp"' EXIT
cat > "$tmp/config.json" <<EOF
{"n": 512, "d": 32, "machines": [1, 2, 4], "max_iters": 120,
 "target_subopt": 1e-3, "out_dir": "$tmp/out"}
EOF
printf '%s\n' \
  '{"query":"fastest_to","eps":1e-2}' \
  '{"query":"fastest_to","eps":1e-2,"max_machines":2}' \
  '{"query":"best_at","budget":10}' \
  | cargo run --release --quiet -- serve --native --config "$tmp/config.json" \
  > "$tmp/serve.out"
cat "$tmp/serve.out"
[ "$(wc -l < "$tmp/serve.out")" -eq 3 ]
grep -q '"predicted_seconds"' "$tmp/serve.out"
grep -q '"predicted_suboptimality"' "$tmp/serve.out"
if grep -q '"ok":false' "$tmp/serve.out"; then
  echo "serve smoke returned an error response" >&2
  exit 1
fi
grep -q '"barrier_mode":"bsp"' "$tmp/serve.out"
echo "serve smoke OK"

# TCP serve smoke: the concurrent front end end to end — an ephemeral
# port published through --port-file, a mixed serve-load burst from 4
# client threads, a stats query with finite latency percentiles, and a
# graceful wire shutdown after which the server must exit 0. Reuses the
# registry the stdin smoke just fitted.
cargo run --release --quiet -- serve --native --config "$tmp/config.json" \
  --tcp 127.0.0.1:0 --workers 2 --port-file "$tmp/serve.port" \
  > "$tmp/tcp_serve.out" 2>&1 &
serve_pid=$!
for _ in $(seq 1 100); do
  [ -s "$tmp/serve.port" ] && break
  sleep 0.1
done
[ -s "$tmp/serve.port" ] || { cat "$tmp/tcp_serve.out" >&2; exit 1; }
addr="$(tr -d '[:space:]' < "$tmp/serve.port")"
cargo run --release --quiet -- serve-load --addr "$addr" --clients 4 --queries 50 \
  --json "$tmp/load.json" --shutdown > "$tmp/load.out"
cat "$tmp/load.out"
grep -q '"query":"stats"' "$tmp/load.out"
grep -q '"p50_us":' "$tmp/load.out"
grep -q '"query":"shutdown"' "$tmp/load.out"
if grep -q '"p50_us":null' "$tmp/load.out"; then
  echo "TCP serve smoke: non-finite latency percentiles" >&2
  exit 1
fi
grep -q '"qps":' "$tmp/load.json"
wait "$serve_pid"
echo "tcp serve smoke OK"

# SSP smoke: the barrier-mode scenario end to end on a tiny config —
# short iteration budget and a small advisor_iter_cap keep this well
# inside the CI time budget.
cat > "$tmp/ssp.json" <<EOF
{"n": 256, "d": 16, "machines": [1, 2, 4, 8], "max_iters": 40,
 "target_subopt": 1e-2, "advisor_iter_cap": 2000,
 "algorithms": ["local-sgd"],
 "barrier_modes": ["bsp", "ssp:2", "async"], "out_dir": "$tmp/ssp_out"}
EOF
cargo run --release --quiet -- repro --figure ssp --native --config "$tmp/ssp.json"
grep -q '^ssp:' "$tmp/ssp_out/summaries.txt"
test -f "$tmp/ssp_out/ssp_barrier_modes.csv"
echo "ssp smoke OK"

# Hetero smoke: the fleet scenario end to end — a tiny mixed fleet
# (uniform local48 next to a slow-node variant) across three barrier
# modes, with time- and dollar-to-target in the CSV, plus one
# cheapest_to query through the serve loop.
cat > "$tmp/hetero.json" <<EOF
{"n": 256, "d": 16, "machines": [1, 2, 4, 8], "max_iters": 40,
 "target_subopt": 1e-2, "advisor_iter_cap": 2000,
 "algorithms": ["local-sgd"],
 "barrier_modes": ["bsp", "ssp:2", "async"],
 "fleets": ["local48", "local48*0.25:slow=3x"],
 "out_dir": "$tmp/hetero_out"}
EOF
cargo run --release --quiet -- repro --figure hetero --native --config "$tmp/hetero.json"
grep -q '^hetero:' "$tmp/hetero_out/summaries.txt"
test -f "$tmp/hetero_out/hetero_fleets.csv"
grep -q 'dollars_to_target' "$tmp/hetero_out/hetero_fleets.csv"
# ε = 0.1 sits far above any fitted prediction floor (see the serve
# tests), so every variant can answer and the response must be ok:true.
printf '%s\n' '{"query":"cheapest_to","eps":0.1,"barrier_mode":"any","fleet":"any"}' \
  | cargo run --release --quiet -- serve --native --config "$tmp/hetero.json" \
  > "$tmp/cheapest.out"
cat "$tmp/cheapest.out"
grep -q '"predicted_dollars"' "$tmp/cheapest.out"
grep -q '"fleet"' "$tmp/cheapest.out"
if grep -q '"ok":false' "$tmp/cheapest.out"; then
  echo "cheapest_to smoke returned an error response" >&2
  exit 1
fi
echo "hetero smoke OK"

# Workloads smoke: the objective axis end to end — a tiny
# `repro --figure workloads` on a ridge-first grid, then one
# workload-filtered fastest_to query through a freshly fitted registry
# (workload pairs persisted in the artifacts, filter honored on the
# wire).
cat > "$tmp/workloads.json" <<EOF
{"n": 256, "d": 16, "machines": [1, 2, 4], "max_iters": 40,
 "target_subopt": 1e-2, "advisor_iter_cap": 2000,
 "algorithms": ["cocoa+", "minibatch-sgd"],
 "workloads": ["hinge", "ridge"],
 "out_dir": "$tmp/workloads_out"}
EOF
cargo run --release --quiet -- repro --figure workloads --native \
  --config "$tmp/workloads.json"
grep -q '^workloads:' "$tmp/workloads_out/summaries.txt"
test -f "$tmp/workloads_out/workloads_crossover.csv"
# ε = 0.5 sits far above any fitted prediction floor, so every variant
# can answer; the ridge-filtered response must name its workload.
printf '%s\n' '{"query":"fastest_to","eps":0.5,"workload":"ridge"}' \
  | cargo run --release --quiet -- serve --native --config "$tmp/workloads.json" \
  > "$tmp/workload_query.out"
cat "$tmp/workload_query.out"
grep -q '"workload":"ridge"' "$tmp/workload_query.out"
grep -q '"predicted_seconds"' "$tmp/workload_query.out"
if grep -q '"ok":false' "$tmp/workload_query.out"; then
  echo "workload-filtered serve smoke returned an error response" >&2
  exit 1
fi
echo "workloads smoke OK"

# Data smoke: the data-scenario axis end to end — a tiny
# `repro --figure data` over dense vs a sparse+skewed scenario (the
# committed demo config's shape, shrunk), then one scenario-filtered
# fastest_to query through a freshly fitted registry (per-scenario
# model pairs persisted, the `data` filter honored on the wire).
cat > "$tmp/data.json" <<EOF
{"n": 256, "d": 16, "machines": [1, 2, 4], "max_iters": 40,
 "target_subopt": 1e-2, "advisor_iter_cap": 2000,
 "algorithms": ["cocoa+", "minibatch-sgd"],
 "data_scenarios": ["dense", "sparse:0.05+skew:0.5"],
 "out_dir": "$tmp/data_out"}
EOF
cargo run --release --quiet -- repro --figure data --native \
  --config "$tmp/data.json"
grep -q '^data:' "$tmp/data_out/summaries.txt"
test -f "$tmp/data_out/data_crossover.csv"
# ε = 0.5 sits far above any fitted prediction floor, so every variant
# can answer; the scenario-filtered response must name its scenario.
printf '%s\n' '{"query":"fastest_to","eps":0.5,"data":"sparse:0.05+skew:0.5"}' \
  | cargo run --release --quiet -- serve --native --config "$tmp/data.json" \
  > "$tmp/data_query.out"
cat "$tmp/data_query.out"
grep -q '"data":"sparse:0.05+skew:0.5"' "$tmp/data_query.out"
grep -q '"predicted_seconds"' "$tmp/data_query.out"
if grep -q '"ok":false' "$tmp/data_query.out"; then
  echo "data-filtered serve smoke returned an error response" >&2
  exit 1
fi
echo "data smoke OK"

# Elastic smoke: the failure scenario end to end — a tiny grid, one
# preemption at 25% of the running plan's time-to-target, advisor
# re-planning every 5 iterations. The re-planned run must reach the
# target (non-empty t_replanned cell, column 5 of the compare row) and
# the event timeline must record the preemption.
cat > "$tmp/elastic.json" <<EOF
{"n": 256, "d": 16, "machines": [1, 2, 4, 8], "max_iters": 60,
 "target_subopt": 1e-2, "advisor_iter_cap": 2000,
 "algorithms": ["cocoa+"], "out_dir": "$tmp/elastic_out"}
EOF
cargo run --release --quiet -- repro --figure elastic --native \
  --config "$tmp/elastic.json"
grep -q '^elastic:' "$tmp/elastic_out/summaries.txt"
test -f "$tmp/elastic_out/elastic_events.csv"
[ "$(wc -l < "$tmp/elastic_out/elastic_events.csv")" -ge 2 ]
grep -q '^preempt,' "$tmp/elastic_out/elastic_events.csv"
test -f "$tmp/elastic_out/elastic_compare.csv"
t_replanned="$(tail -n 1 "$tmp/elastic_out/elastic_compare.csv" | cut -d, -f5)"
if [ -z "$t_replanned" ]; then
  echo "elastic smoke: re-planned run did not reach the target" >&2
  exit 1
fi
echo "elastic smoke OK"

# Calibration smoke: measured hardware profiles end to end —
# `calibrate --quick` fits an artifact from real on-host
# microbenchmarks, `advise` answers on the measured profile, the serve
# stats response carries calibration provenance, and
# `repro --figure calib` prices assumed-vs-measured advice into
# calib_compare.csv.
cargo run --release --quiet -- calibrate --quick --name cihost --out "$tmp/calib" \
  > "$tmp/calibrate.out"
cat "$tmp/calibrate.out"
test -f "$tmp/calib/cihost.json"
grep -q 'hemingway-calib/v1' "$tmp/calib/cihost.json"
grep -q 'generation' "$tmp/calibrate.out"
cat > "$tmp/calib.json" <<EOF
{"n": 256, "d": 16, "machines": [1, 2, 4], "max_iters": 40,
 "target_subopt": 1e-2, "advisor_iter_cap": 2000,
 "algorithms": ["cocoa+", "minibatch-sgd"],
 "profile": "measured:cihost", "profile_dir": "$tmp/calib",
 "out_dir": "$tmp/calib_out"}
EOF
cargo run --release --quiet -- advise --native --eps 0.5 --config "$tmp/calib.json" \
  > "$tmp/calib_advise.out"
cat "$tmp/calib_advise.out"
grep -q '^fastest to' "$tmp/calib_advise.out"
printf '%s\n' '{"query":"stats"}' \
  | cargo run --release --quiet -- serve --native --config "$tmp/calib.json" \
  > "$tmp/calib_stats.out"
cat "$tmp/calib_stats.out"
grep -q '"calibration"' "$tmp/calib_stats.out"
grep -q '"name":"cihost"' "$tmp/calib_stats.out"
cargo run --release --quiet -- repro --figure calib --native --config "$tmp/calib.json"
grep -q '^calib:' "$tmp/calib_out/summaries.txt"
test -f "$tmp/calib_out/calib_compare.csv"
[ "$(wc -l < "$tmp/calib_out/calib_compare.csv")" -ge 2 ]
echo "calib smoke OK"

# Resume smoke: a tiny sweep, then tear the trace-store manifest tail
# (as a kill mid-append would) and rerun with --resume. Planning runs
# off the torn manifest so exactly one cell replans, but the shard
# files are ground truth: nothing recomputes (0 misses) and both sweep
# CSVs must come back byte-identical.
cat > "$tmp/sweep.json" <<EOF
{"n": 256, "d": 16, "machines": [1, 2, 4], "max_iters": 40,
 "target_subopt": 1e-2, "out_dir": "$tmp/sweep_out"}
EOF
cargo run --release --quiet -- sweep --native --seeds 2 --config "$tmp/sweep.json"
cp "$tmp/sweep_out/sweep_cocoa+.csv" "$tmp/sweep_first.csv"
cp "$tmp/sweep_out/sweep_cocoa+_agg.csv" "$tmp/agg_first.csv"
manifest="$tmp/sweep_out/cache/MANIFEST"
test -f "$manifest"
size="$(wc -c < "$manifest")"
head -c "$((size - 3))" "$manifest" > "$manifest.torn"
mv "$manifest.torn" "$manifest"
cargo run --release --quiet -- sweep --native --seeds 2 --resume \
  --config "$tmp/sweep.json" > "$tmp/sweep_resume.out"
cat "$tmp/sweep_resume.out"
grep -q 'cells already in the trace store; 1 to run' "$tmp/sweep_resume.out"
grep -q 'cache: 6 hits / 0 misses' "$tmp/sweep_resume.out"
cmp "$tmp/sweep_first.csv" "$tmp/sweep_out/sweep_cocoa+.csv"
cmp "$tmp/agg_first.csv" "$tmp/sweep_out/sweep_cocoa+_agg.csv"
echo "resume smoke OK"

# Bench snapshots: regenerate BENCH_workloads.json, BENCH_sweep.json,
# BENCH_serve.json, BENCH_data.json and BENCH_calib.json at the repo
# root (cache-probe
# hit/miss latency sharded-v5 vs flat-v4, streamed cells/sec, aggregate
# throughput, TCP serve qps single- vs multi-client, dense-vs-CSR
# kernel cost and skewed-partition overhead — see
# benches/bench_main.rs).
# Timings are machine-local; set HEMINGWAY_BENCH=0 to skip on
# contended runners.
if [ "${HEMINGWAY_BENCH:-1}" = "1" ]; then
  cargo bench --bench bench_main
  test -f ../BENCH_workloads.json
  test -f ../BENCH_sweep.json
  test -f ../BENCH_serve.json
  test -f ../BENCH_data.json
  test -f ../BENCH_calib.json
  echo "bench snapshots OK"
fi

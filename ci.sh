#!/usr/bin/env bash
# Tier-1 gate: build, tests, formatting. Run from the repo root.
# HEMINGWAY_THREADS=1 pins the sweep engine's scheduling for
# reproducible logs; traces are byte-identical at any thread count.
set -euo pipefail
cd "$(dirname "$0")/rust"

export HEMINGWAY_THREADS="${HEMINGWAY_THREADS:-1}"

cargo build --release
cargo test -q
cargo fmt --check

"""AOT lowering driver: python -m compile.aot --out-dir ../artifacts

Lowers every (kernel × partition shape) in the experiment grid to an
HLO-text artifact and writes `manifest.json` describing the ABI. This
is the ONLY python entry point in the system; it runs at build time
(`make artifacts`) and never again.

The default grid covers the paper's sweep m ∈ {1, 2, 4, …, 128} over
the default dataset (n = 8192, d = 128): partition sizes n/m. Override
with --n/--d/--machines for other experiment configs.
"""

import argparse
import hashlib
import json
import os
import sys

from .model import kernel_specs, lower_to_hlo_text


def dtype_name(aval) -> str:
    return str(aval.dtype)


def build_grid(n: int, machines: list[int]) -> list[int]:
    """Distinct padded partition sizes for the machine sweep."""
    sizes = set()
    for m in machines:
        n_loc = (n + m - 1) // m
        sizes.add(n_loc)
    return sorted(sizes, reverse=True)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=8192, help="global dataset rows")
    ap.add_argument("--d", type=int, default=128, help="feature dimension")
    ap.add_argument(
        "--machines",
        default="1,2,4,8,16,32,64,128",
        help="comma-separated machine counts in the sweep",
    )
    ap.add_argument(
        "--kernels",
        default="cocoa_local,grad,local_sgd",
        help="comma-separated kernel subset to lower",
    )
    ap.add_argument(
        "--h-frac",
        type=float,
        default=1.0,
        help="local epoch length as a fraction of partition size",
    )
    ap.add_argument(
        "--impl",
        default="lax",
        choices=["lax", "pallas"],
        help="implementation lowered for the sequential kernels: the "
        "step-identical lax mirrors (CPU production default) or the "
        "canonical Pallas kernels (TPU target / correctness study); "
        "see kernels/lax_mirrors.py",
    )
    args = ap.parse_args()

    machines = [int(x) for x in args.machines.split(",")]
    wanted = set(args.kernels.split(","))
    out_dir = args.out_dir
    os.makedirs(out_dir, exist_ok=True)

    entries = []
    for n_loc in build_grid(args.n, machines):
        h_steps = max(1, int(round(args.h_frac * n_loc)))
        specs = kernel_specs(n_loc, args.d, h_steps, impl=args.impl)
        for name, (fn, example_args) in specs.items():
            if name not in wanted:
                continue
            fname = f"{name}_n{n_loc}_d{args.d}.hlo.txt"
            path = os.path.join(out_dir, fname)
            text = lower_to_hlo_text(fn, example_args)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            entries.append(
                {
                    "kernel": name,
                    "file": fname,
                    "n_loc": n_loc,
                    "d": args.d,
                    "h_steps": h_steps if name != "grad" else 0,
                    "inputs": [
                        {"shape": list(a.shape), "dtype": dtype_name(a)}
                        for a in example_args
                    ],
                    "sha256_16": digest,
                }
            )
            print(f"  lowered {fname} ({len(text)} chars)", file=sys.stderr)

    manifest = {
        "version": 1,
        "n": args.n,
        "d": args.d,
        "machines": machines,
        "h_frac": args.h_frac,
        "impl": args.impl,
        "artifacts": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(
        f"wrote {len(entries)} artifacts + manifest.json to {out_dir}",
        file=sys.stderr,
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

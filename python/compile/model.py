"""Layer-2 JAX step functions — the per-partition computations each
distributed optimization algorithm runs inside one BSP iteration.

Each function here is a thin, jit-able composition around exactly one
Pallas kernel; `aot.py` lowers each (function × partition shape) pair to
an HLO-text artifact the Rust coordinator executes through PJRT. The
function signatures (argument order, shapes, dtypes) are the ABI between
the layers and are recorded in `artifacts/manifest.json`.

Conventions shared with the Rust side (`rust/src/optim/problem.rs`):

* labels y ∈ {−1, +1}, 0 on padded rows; mask ∈ {0, 1};
* dual parametrization a ∈ [0,1]^n with w(a) = (1/λn) Σ a_i y_i x_i;
* `scal` packs scalars as an f32 vector so artifacts stay scalar-free.
"""

import jax
import jax.numpy as jnp

from .kernels import hinge_stats, pegasos_epoch, sdca_epoch


def cocoa_local_step(x, y, mask, alpha, w, scal, seed, *, h_steps):
    """CoCoA / CoCoA+ local solver: one SDCA epoch on a partition.

    scal = [lambda_n, sigma_prime]. Returns (alpha_new, delta_w).
    σ' = 1 → CoCoA (coordinator averages); σ' = m → CoCoA+ (adds).
    """
    return sdca_epoch(x, y, mask, alpha, w, scal, seed, h_steps=h_steps)


def grad_step(x, y, weights, w):
    """Weighted hinge statistics for GD / mini-batch SGD / objective eval.

    Returns (grad_sum (d,), stats (2,) = [hinge_sum, correct_sum]).
    All normalization (1/n, λw, step size) happens in the coordinator.
    """
    return hinge_stats(x, y, weights, w)


def local_sgd_step(x, y, mask, w, scal, seed, *, h_steps):
    """Splash-style local Pegasos epoch. scal = [lambda, t0].

    Returns the machine's new local iterate (the coordinator averages).
    """
    return pegasos_epoch(x, y, mask, w, scal, seed, h_steps=h_steps)


# ---------------------------------------------------------------------------
# Shape specs + lowering helpers used by aot.py and the pytest suite.
# ---------------------------------------------------------------------------

def f32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def kernel_specs(n_loc: int, d: int, h_steps: int, impl: str = "pallas"):
    """The (name → (callable, example_args)) table for one partition shape.

    `h_steps` is baked into the artifact (static loop bound); the
    default is one pass over the partition (`h_steps = n_loc`).

    `impl` selects the implementation lowered into the artifact for the
    *sequential* kernels (cocoa_local, local_sgd):

    * ``"pallas"`` — the canonical L1 Pallas kernels (interpret=True).
    * ``"lax"``    — the step-identical jax.lax mirrors
      (`kernels/lax_mirrors.py`), used for CPU production artifacts
      because interpret-mode discharge makes the in-kernel epoch loop
      O(h·n_loc) in memory traffic (see that module's docstring).

    `grad` is always the Pallas kernel — it is the data-parallel,
    MXU-shaped hot-spot Pallas exists for, and it lowers efficiently.
    """
    if impl == "lax":
        from .kernels.lax_mirrors import make_pegasos, make_sdca

        cocoa_fn = lambda x, y, mk, a, w, s, sd: make_sdca(h_steps)(x, y, mk, a, w, s, sd)
        sgd_fn = lambda x, y, mk, w, s, sd: make_pegasos(h_steps)(x, y, mk, w, s, sd)
    elif impl == "pallas":
        cocoa_fn = lambda x, y, mk, a, w, s, sd: cocoa_local_step(
            x, y, mk, a, w, s, sd, h_steps=h_steps
        )
        sgd_fn = lambda x, y, mk, w, s, sd: local_sgd_step(
            x, y, mk, w, s, sd, h_steps=h_steps
        )
    else:
        raise ValueError(f"unknown impl '{impl}'")

    return {
        "cocoa_local": (
            cocoa_fn,
            (
                f32((n_loc, d)),  # x
                f32((n_loc, 1)),  # y
                f32((n_loc, 1)),  # mask
                f32((n_loc, 1)),  # alpha
                f32((d,)),        # w
                f32((2,)),        # [lambda_n, sigma_prime]
                i32((1,)),        # seed
            ),
        ),
        "grad": (
            grad_step,
            (
                f32((n_loc, d)),  # x
                f32((n_loc, 1)),  # y
                f32((n_loc, 1)),  # weights
                f32((d,)),        # w
            ),
        ),
        "local_sgd": (
            sgd_fn,
            (
                f32((n_loc, d)),  # x
                f32((n_loc, 1)),  # y
                f32((n_loc, 1)),  # mask
                f32((d,)),        # w
                f32((2,)),        # [lambda, t0]
                i32((1,)),        # seed
            ),
        ),
    }


def lower_to_hlo_text(fn, example_args) -> str:
    """Lower a jitted function to HLO *text* (the interchange format).

    jax ≥ 0.5 serialized HloModuleProtos carry 64-bit instruction ids
    that xla_extension 0.5.1 rejects; the text parser reassigns ids, so
    text round-trips cleanly (see /opt/xla-example/README.md).
    """
    from jax._src.lib import xla_client as xc

    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()

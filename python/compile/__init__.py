"""Build-time compile path: L1 Pallas kernels + L2 JAX model + AOT lowering.

Nothing in this package is imported at runtime — `make artifacts` runs
aot.py once and the Rust coordinator only touches artifacts/*.hlo.txt.
"""

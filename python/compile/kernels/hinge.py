"""Pallas kernel: weighted hinge-loss statistics over a partition.

One kernel serves four call sites in the coordinator:

* full-gradient descent      — weights = validity mask
* mini-batch SGD             — weights = mask ∘ Bernoulli sample
* primal objective evaluation — weights = mask (hinge sum output)
* accuracy reporting         — weighted correct-prediction count

Returns raw *sums* (no 1/n, no λ terms) so the Rust side owns all
scaling — that keeps one artifact valid for every use.

The kernel is row-tiled with a BlockSpec grid: X is streamed through
VMEM-sized (tile × d) blocks while the (d,) gradient accumulator and
the (2,) stats accumulator stay resident across the grid — the classic
MXU-friendly reduction schedule (see DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hinge_kernel(x_ref, y_ref, wt_ref, w_ref, grad_ref, stats_ref):
    tile = pl.program_id(0)

    @pl.when(tile == 0)
    def _init():
        grad_ref[...] = jnp.zeros_like(grad_ref)
        stats_ref[...] = jnp.zeros_like(stats_ref)

    x = x_ref[...]                       # (t, d) block
    y = y_ref[...][:, 0]                 # (t,)
    wt = wt_ref[...][:, 0]               # (t,)
    w = w_ref[...]                       # (d,)

    scores = x @ w                       # (t,) — the MXU-shaped op
    margins = 1.0 - y * scores
    active = (margins > 0.0).astype(jnp.float32) * wt

    # Σ_i wt_i 1[margin_i > 0] (−y_i x_i)
    grad_ref[...] = grad_ref[...] + (-(active * y)) @ x
    hinge = jnp.sum(wt * jnp.maximum(margins, 0.0))
    correct = jnp.sum(wt * (scores * y > 0.0).astype(jnp.float32))
    stats_ref[...] = stats_ref[...] + jnp.stack([hinge, correct])


def pick_tile(n_loc: int) -> int:
    """Largest power-of-two row tile ≤ 512 that divides n_loc."""
    t = 1
    while t * 2 <= min(n_loc, 512) and n_loc % (t * 2) == 0:
        t *= 2
    return t


def hinge_stats(x, y, weights, w):
    """Weighted hinge statistics; returns ``(grad_sum, [hinge_sum, correct_sum])``.

    Shapes: x (n_loc, d); y/weights (n_loc, 1); w (d,). f32.
    """
    n_loc, d = x.shape
    tile = pick_tile(n_loc)
    grid = n_loc // tile
    return pl.pallas_call(
        functools.partial(_hinge_kernel),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((d,), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.float32),
        ),
        interpret=True,
    )(x, y, weights, w)

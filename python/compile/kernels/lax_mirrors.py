"""jax.lax mirrors of the *sequential* Pallas kernels, used for the
CPU production artifacts.

Why these exist (§Perf optimization B): `pallas_call(interpret=True)`
discharges ref loads/stores into functional ops with full-buffer copies
per loop step, so the in-kernel SDCA/Pegasos epochs lower to HLO with
O(h·n_loc) memory traffic — 6.4 µs/step at n_loc=8192 vs the ~30 ns/step
XLA achieves for an in-place dynamic-update-slice loop. On a real TPU
the Pallas kernel compiles through Mosaic (no discharge, VMEM-resident
state) and this pathology does not exist; on this CPU-only image we
lower these mathematically identical lax implementations instead.

The Pallas kernels remain the canonical L1 definition: pytest
(`tests/test_lax_mirrors.py`) requires bit-tight agreement between the
two on every shape it sweeps, so the artifact behaviour is still
pinned to the Pallas semantics.
"""

import functools

import jax
import jax.numpy as jnp

from .lcg import lcg_index, lcg_next


def sdca_epoch_lax(x, y, mask, alpha, w, scal, seed, *, h_steps: int):
    """Identical update sequence to `sdca.sdca_epoch` (same LCG stream).

    Shapes: x (n_loc, d); y/mask/alpha (n_loc, 1); w (d,); scal (2,);
    seed (1,) int32. Returns (alpha_new (n_loc, 1), delta_w (d,)).
    """
    n_loc, d = x.shape
    lambda_n = scal[0]
    sigma_p = scal[1]
    state0 = jax.lax.bitcast_convert_type(seed[0], jnp.uint32)
    a0 = alpha[:, 0]
    y1 = y[:, 0]
    m1 = mask[:, 0]

    def body(_, carry):
        a, dw, state = carry
        state = lcg_next(state)
        j = lcg_index(state, n_loc)
        xj = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=0)[0]
        yj = y1[j]
        mj = m1[j]
        aj = a[j]
        w_eff = w + sigma_p * dw
        qj = jnp.sum(xj * xj)
        margin = 1.0 - yj * jnp.sum(xj * w_eff)
        denom = jnp.maximum(sigma_p * qj, 1e-12)
        step = jnp.where(qj > 0.0, lambda_n * margin / denom, 0.0)
        a_new = jnp.clip(aj + step, 0.0, 1.0)
        delta = (a_new - aj) * mj
        a = a.at[j].set(aj + delta)
        dw = dw + (delta * yj / lambda_n) * xj
        return (a, dw, state)

    a, dw, _ = jax.lax.fori_loop(
        0, h_steps, body, (a0, jnp.zeros(d, jnp.float32), state0)
    )
    return a.reshape(n_loc, 1), dw


def pegasos_epoch_lax(x, y, mask, w, scal, seed, *, h_steps: int):
    """Identical update sequence to `pegasos.pegasos_epoch`."""
    n_loc, d = x.shape
    lam = scal[0]
    t0 = scal[1]
    state0 = jax.lax.bitcast_convert_type(seed[0], jnp.uint32)
    y1 = y[:, 0]
    m1 = mask[:, 0]

    def body(t, carry):
        wv, state = carry
        state = lcg_next(state)
        j = lcg_index(state, n_loc)
        xj = jax.lax.dynamic_slice_in_dim(x, j, 1, axis=0)[0]
        yj = y1[j]
        mj = m1[j]
        eta = 1.0 / (lam * (t0 + t.astype(jnp.float32) + 1.0))
        active = (1.0 - yj * jnp.sum(xj * wv) > 0.0).astype(jnp.float32)
        shrink = 1.0 - eta * lam * mj
        wv = shrink * wv + (eta * active * mj * yj) * xj
        return (wv, state)

    wv, _ = jax.lax.fori_loop(0, h_steps, body, (w, state0))
    return wv


# Convenience partials matching the kernel_specs call signatures.
def make_sdca(h_steps):
    return functools.partial(sdca_epoch_lax, h_steps=h_steps)


def make_pegasos(h_steps):
    return functools.partial(pegasos_epoch_lax, h_steps=h_steps)

"""Pallas kernel: one local Pegasos (SGD) epoch — the Splash-style
local-update solver.

Each machine runs `h_steps` of projected stochastic (sub)gradient on its
partition with the Pegasos step size η_t = 1/(λ (t0 + t)); the
coordinator then averages iterates across machines (Zhang & Jordan's
Splash averages reweighted local updates; iterate averaging is the
standard simplification and exhibits the same convergence-vs-m
degradation the paper plots in Fig 1(c)).

`t0` carries the global step count across outer iterations so the
effective step-size schedule is continuous.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .lcg import lcg_index, lcg_next


def _pegasos_kernel(
    x_ref,      # (n_loc, d) f32
    y_ref,      # (n_loc, 1) f32
    mask_ref,   # (n_loc, 1) f32
    w_ref,      # (d,)       f32
    scal_ref,   # (2,)       f32 — [lambda, t0]
    seed_ref,   # (1,)       i32
    w_out,      # (d,)       f32
    *,
    h_steps: int,
    n_loc: int,
):
    w_out[...] = w_ref[...]
    lam = scal_ref[0]
    t0 = scal_ref[1]
    state0 = jax.lax.bitcast_convert_type(seed_ref[0], jnp.uint32)

    def body(t, state):
        state = lcg_next(state)
        j = lcg_index(state, n_loc)
        xj = pl.load(x_ref, (pl.dslice(j, 1), slice(None)))[0]
        yj = pl.load(y_ref, (pl.dslice(j, 1), slice(None)))[0, 0]
        mj = pl.load(mask_ref, (pl.dslice(j, 1), slice(None)))[0, 0]

        w = w_out[...]
        eta = 1.0 / (lam * (t0 + t.astype(jnp.float32) + 1.0))
        active = (1.0 - yj * jnp.sum(xj * w) > 0.0).astype(jnp.float32)
        # Regularizer shrink applies on every (valid) step; the loss
        # term only when the margin is violated.
        shrink = 1.0 - eta * lam * mj
        w_out[...] = shrink * w + (eta * active * mj * yj) * xj
        return state

    jax.lax.fori_loop(0, h_steps, body, state0)


def pegasos_epoch(x, y, mask, w, scal, seed, *, h_steps: int):
    """Run one local Pegasos epoch; returns the new local iterate ``w``.

    Shapes: x (n_loc, d); y/mask (n_loc, 1); w (d,); scal (2,) =
    [lambda, t0]; seed (1,) int32.
    """
    n_loc, d = x.shape
    kernel = functools.partial(_pegasos_kernel, h_steps=h_steps, n_loc=n_loc)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=True,
    )(x, y, mask, w, scal, seed)

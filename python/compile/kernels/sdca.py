"""Pallas kernel: one local SDCA epoch (the CoCoA / CoCoA+ local solver).

This is the compute hot-spot of the whole system: every outer BSP
iteration of CoCoA runs one of these per partition. The entire epoch
(`h_steps` randomized coordinate updates) lives inside a single kernel
invocation so the AOT artifact contains one fused XLA while-loop instead
of `h_steps` host round-trips.

Problem: hinge-loss SVM dual with box constraints. We parametrize the
dual variable as ``a_i ∈ [0, 1]`` with primal correspondence
``w(a) = (1/(λ n)) Σ_i a_i y_i x_i``. The closed-form SDCA step for
coordinate j (Shalev-Shwartz & Zhang 2013), generalized with CoCoA+'s
subproblem scaling σ':

    w_eff = w + σ' · dw                      (dw = local Δw so far)
    Δ     = clip(a_j + λn (1 − y_j x_jᵀ w_eff) / (σ' ‖x_j‖²), 0, 1) − a_j
    a_j  += Δ ;  dw += Δ y_j x_j / (λn)

σ' = 1 reproduces CoCoA (averaging, updates later scaled by 1/m in the
coordinator); σ' = m reproduces CoCoA+ (adding).

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
testbed is a CPU cluster, so there is no GPU kernel to port; the TPU
shaping here keeps `w`, `dw` and the dual block resident in VMEM-like
scratch (they are kernel outputs, mutated in place) for the whole epoch
while rows of X are gathered on demand — the HBM↔VMEM analogue of
CoCoA keeping its local state in executor memory across a pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .lcg import lcg_index, lcg_next


def _sdca_kernel(
    x_ref,      # (n_loc, d)  f32 — local data rows
    y_ref,      # (n_loc, 1)  f32 — labels in {-1, +1} (0 on padded rows)
    mask_ref,   # (n_loc, 1)  f32 — 1 for valid rows, 0 for padding
    alpha_ref,  # (n_loc, 1)  f32 — dual variables a ∈ [0, 1]
    w_ref,      # (d,)        f32 — global weight vector (read-only)
    scal_ref,   # (2,)        f32 — [lambda_n = λ·n_global, sigma_prime]
    seed_ref,   # (1,)        i32 — LCG start state (bitcast to u32)
    alpha_out,  # (n_loc, 1)  f32 — updated duals
    dw_out,     # (d,)        f32 — local Δw = (1/λn) X_kᵀ(Δa ∘ y)
    *,
    h_steps: int,
    n_loc: int,
):
    alpha_out[...] = alpha_ref[...]
    dw_out[...] = jnp.zeros_like(dw_out)
    lambda_n = scal_ref[0]
    sigma_p = scal_ref[1]
    state0 = jax.lax.bitcast_convert_type(seed_ref[0], jnp.uint32)

    def body(_, state):
        state = lcg_next(state)
        j = lcg_index(state, n_loc)
        xj = pl.load(x_ref, (pl.dslice(j, 1), slice(None)))[0]      # (d,)
        yj = pl.load(y_ref, (pl.dslice(j, 1), slice(None)))[0, 0]
        mj = pl.load(mask_ref, (pl.dslice(j, 1), slice(None)))[0, 0]
        aj = pl.load(alpha_out, (pl.dslice(j, 1), slice(None)))[0, 0]

        w_eff = w_ref[...] + sigma_p * dw_out[...]
        qj = jnp.sum(xj * xj)
        margin = 1.0 - yj * jnp.sum(xj * w_eff)
        denom = jnp.maximum(sigma_p * qj, 1e-12)
        step = jnp.where(qj > 0.0, lambda_n * margin / denom, 0.0)
        a_new = jnp.clip(aj + step, 0.0, 1.0)
        delta = (a_new - aj) * mj

        pl.store(
            alpha_out,
            (pl.dslice(j, 1), slice(None)),
            jnp.reshape(aj + delta, (1, 1)),
        )
        dw_out[...] = dw_out[...] + (delta * yj / lambda_n) * xj
        return state

    jax.lax.fori_loop(0, h_steps, body, state0)


def sdca_epoch(x, y, mask, alpha, w, scal, seed, *, h_steps: int):
    """Run one local SDCA epoch; returns ``(alpha_new, delta_w)``.

    Shapes: x (n_loc, d); y/mask/alpha (n_loc, 1); w (d,); scal (2,);
    seed (1,) int32. All f32 except the seed.
    """
    n_loc, d = x.shape
    kernel = functools.partial(_sdca_kernel, h_steps=h_steps, n_loc=n_loc)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n_loc, 1), jnp.float32),
            jax.ShapeDtypeStruct((d,), jnp.float32),
        ),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, y, mask, alpha, w, scal, seed)

"""Layer-1 Pallas kernels (interpret=True) and their pure-numpy oracles."""

from .hinge import hinge_stats
from .pegasos import pegasos_epoch
from .sdca import sdca_epoch

__all__ = ["hinge_stats", "pegasos_epoch", "sdca_epoch"]

"""Pure-numpy reference oracles for every Pallas kernel.

Written as explicit python loops over the *same* LCG stream as the
kernels, so pytest can require `assert_allclose` agreement. These are
intentionally independent of jax.lax control flow — a genuinely
separate implementation, not a refactoring of the kernel.
"""

import numpy as np

from .lcg import lcg_index_np, lcg_next_np


def sdca_epoch_ref(x, y, mask, alpha, w, lambda_n, sigma_prime, seed, h_steps):
    """Reference local SDCA epoch. Returns (alpha_new, delta_w).

    Arguments mirror kernels.sdca.sdca_epoch with scalars unpacked;
    y/mask/alpha may be (n,) or (n,1).
    """
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    mask = np.asarray(mask, dtype=np.float64).reshape(-1)
    a = np.asarray(alpha, dtype=np.float64).reshape(-1).copy()
    w = np.asarray(w, dtype=np.float64)
    n_loc, d = x.shape
    dw = np.zeros(d)
    state = np.uint32(seed)
    for _ in range(h_steps):
        state = lcg_next_np(state)
        j = lcg_index_np(state, n_loc)
        xj = x[j]
        qj = float(xj @ xj)
        w_eff = w + sigma_prime * dw
        margin = 1.0 - y[j] * float(xj @ w_eff)
        denom = max(sigma_prime * qj, 1e-12)
        step = lambda_n * margin / denom if qj > 0.0 else 0.0
        a_new = min(max(a[j] + step, 0.0), 1.0)
        delta = (a_new - a[j]) * mask[j]
        a[j] += delta
        dw += (delta * y[j] / lambda_n) * xj
    return a.reshape(-1, 1).astype(np.float32), dw.astype(np.float32)


def hinge_stats_ref(x, y, weights, w):
    """Reference weighted hinge statistics: (grad_sum, [hinge, correct])."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    wt = np.asarray(weights, dtype=np.float64).reshape(-1)
    w = np.asarray(w, dtype=np.float64)
    scores = x @ w
    margins = 1.0 - y * scores
    active = (margins > 0.0).astype(np.float64) * wt
    grad = -(active * y) @ x
    hinge = float(np.sum(wt * np.maximum(margins, 0.0)))
    correct = float(np.sum(wt * (scores * y > 0.0)))
    return grad.astype(np.float32), np.array([hinge, correct], dtype=np.float32)


def pegasos_epoch_ref(x, y, mask, w, lam, t0, seed, h_steps):
    """Reference local Pegasos epoch. Returns the new iterate w."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    mask = np.asarray(mask, dtype=np.float64).reshape(-1)
    w = np.asarray(w, dtype=np.float64).copy()
    n_loc, _ = x.shape
    state = np.uint32(seed)
    for t in range(h_steps):
        state = lcg_next_np(state)
        j = lcg_index_np(state, n_loc)
        xj = x[j]
        eta = 1.0 / (lam * (t0 + t + 1.0))
        active = 1.0 if (1.0 - y[j] * float(xj @ w)) > 0.0 else 0.0
        shrink = 1.0 - eta * lam * mask[j]
        w = shrink * w + (eta * active * mask[j] * y[j]) * xj
    return w.astype(np.float32)


# ---------------------------------------------------------------------------
# Objective-level references (used by model/aot tests and as the ground
# truth the Rust integration tests compare against via recorded traces).
# ---------------------------------------------------------------------------

def primal_objective(x, y, w, lam):
    """P(w) = λ/2 ‖w‖² + (1/n) Σ hinge(y_i x_iᵀ w) over valid rows."""
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64).reshape(-1)
    w = np.asarray(w, dtype=np.float64)
    valid = y != 0.0
    n = int(valid.sum())
    margins = 1.0 - y[valid] * (x[valid] @ w)
    return 0.5 * lam * float(w @ w) + float(np.maximum(margins, 0.0).sum()) / n


def dual_objective(alpha, y, w, lam, n):
    """D(a) = (1/n) Σ a_i − λ/2 ‖w(a)‖² with w(a) supplied by the caller."""
    a = np.asarray(alpha, dtype=np.float64).reshape(-1)
    w = np.asarray(w, dtype=np.float64)
    return float(a.sum()) / n - 0.5 * lam * float(w @ w)

"""Shared 32-bit LCG used for in-kernel random coordinate selection.

The Rust coordinator keeps a bit-identical mirror of this stream
(`rust/src/util/rng.rs::Lcg32`) so the native-Rust oracle solvers and
the AOT-compiled Pallas kernels can be required to agree numerically in
tests. Constants are the Numerical Recipes LCG; coordinate draws take
the high bits (`(state >> 8) % n`) because the low bits of an LCG have
short periods.
"""

import jax.numpy as jnp
import numpy as np

LCG_A = np.uint32(1664525)
LCG_C = np.uint32(1013904223)


def epoch_seed(seed: int, epoch: int, partition: int) -> np.uint32:
    """Mix (seed, epoch, partition) into an LCG start state.

    Mirrors ``Lcg32::for_epoch`` in Rust exactly (wrapping u32 ops).
    """
    mask = 0xFFFFFFFF
    s = (
        (int(seed) & mask)
        ^ ((int(epoch) * 0x9E3779B9) & mask)
        ^ ((int(partition) * 0x85EBCA6B) & mask)
    )
    if s == 0:
        s = 0x6B79D38B
    return np.uint32(s)


def lcg_next(state):
    """One LCG step on a traced jnp uint32 scalar."""
    return state * jnp.uint32(LCG_A) + jnp.uint32(LCG_C)


def lcg_index(state, n: int):
    """Coordinate draw in [0, n) from a *freshly advanced* state."""
    return ((state >> jnp.uint32(8)) % jnp.uint32(n)).astype(jnp.int32)


def lcg_next_np(state: np.uint32) -> np.uint32:
    """Host-side (numpy) mirror for the pure-python reference oracle."""
    with np.errstate(over="ignore"):
        return np.uint32(state * LCG_A + LCG_C)


def lcg_index_np(state: np.uint32, n: int) -> int:
    return int((int(state) >> 8) % n)

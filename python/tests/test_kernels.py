"""Kernel-vs-oracle correctness: hypothesis sweeps shapes, data and
hyperparameters, asserting allclose agreement between each Pallas kernel
(interpret=True) and its pure-numpy reference."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import hinge_stats, pegasos_epoch, sdca_epoch
from compile.kernels.lcg import epoch_seed
from compile.kernels.ref import (
    dual_objective,
    hinge_stats_ref,
    pegasos_epoch_ref,
    primal_objective,
    sdca_epoch_ref,
)


def make_problem(rng, n, d, masked=0):
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sign(rng.normal(size=(n, 1))).astype(np.float32)
    y[y == 0] = 1.0
    mask = np.ones((n, 1), np.float32)
    if masked:
        idx = rng.choice(n, size=masked, replace=False)
        mask[idx] = 0.0
        y[idx] = 0.0
        x[idx] = 0.0
    return x, y, mask


def seed_arr(s):
    return jnp.array([np.int32(np.uint32(s).view(np.int32))])


# ---------------------------------------------------------------------------
# sdca_epoch
# ---------------------------------------------------------------------------

@given(
    n=st.integers(min_value=2, max_value=48),
    d=st.integers(min_value=1, max_value=24),
    h_mult=st.floats(min_value=0.25, max_value=2.0),
    sigma=st.sampled_from([1.0, 2.0, 8.0]),
    lam=st.sampled_from([1e-4, 1e-2, 1.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_sdca_matches_reference(n, d, h_mult, sigma, lam, seed):
    rng = np.random.default_rng(seed % 1000)
    x, y, mask = make_problem(rng, n, d)
    alpha = rng.uniform(0, 1, size=(n, 1)).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32) * 0.1
    h = max(1, int(h_mult * n))
    lambda_n = lam * n
    s = epoch_seed(seed, 0, 0)
    scal = np.array([lambda_n, sigma], np.float32)

    a_k, dw_k = sdca_epoch(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(alpha),
        jnp.array(w), jnp.array(scal), seed_arr(s), h_steps=h,
    )
    a_r, dw_r = sdca_epoch_ref(x, y, mask, alpha, w, lambda_n, sigma, s, h)
    assert_allclose(np.array(a_k), a_r, rtol=2e-4, atol=2e-5)
    assert_allclose(np.array(dw_k), dw_r, rtol=2e-3, atol=2e-4)


@given(
    n=st.integers(min_value=4, max_value=32),
    d=st.integers(min_value=2, max_value=12),
    masked=st.integers(min_value=1, max_value=3),
)
@settings(max_examples=10, deadline=None)
def test_sdca_respects_padding_mask(n, d, masked):
    """Padded rows must keep alpha = 0 and contribute nothing to dw."""
    rng = np.random.default_rng(n * 100 + d)
    x, y, mask = make_problem(rng, n, d, masked=masked)
    alpha = np.zeros((n, 1), np.float32)
    w = np.zeros(d, np.float32)
    s = epoch_seed(1, 2, 3)
    scal = np.array([1e-2 * n, 1.0], np.float32)
    a_k, _ = sdca_epoch(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(alpha),
        jnp.array(w), jnp.array(scal), seed_arr(s), h_steps=4 * n,
    )
    a_k = np.array(a_k)
    assert np.all(a_k[mask[:, 0] == 0.0] == 0.0)
    assert np.all((a_k >= 0.0) & (a_k <= 1.0))


def test_sdca_improves_dual_objective():
    """Single-machine SDCA must monotonically improve the dual (in
    expectation; we check across whole epochs where it's essentially
    deterministic)."""
    rng = np.random.default_rng(0)
    n, d, lam = 64, 8, 1e-2
    x, y, mask = make_problem(rng, n, d)
    alpha = np.zeros((n, 1), np.float32)
    w = np.zeros(d, np.float32)
    prev = -np.inf
    for ep in range(15):
        s = epoch_seed(9, ep, 0)
        scal = np.array([lam * n, 1.0], np.float32)
        a_new, dw = sdca_epoch(
            jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(alpha),
            jnp.array(w), jnp.array(scal), seed_arr(s), h_steps=n,
        )
        alpha = np.array(a_new)
        w = w + np.array(dw)
        dual = dual_objective(alpha, y, w, lam, n)
        assert dual >= prev - 1e-6, f"dual decreased at epoch {ep}"
        prev = dual
    # And the duality gap should have narrowed substantially from its
    # starting value of 1.0 (P(0) = 1, D(0) = 0 at alpha = w = 0).
    p = primal_objective(x, y, w, lam)
    assert p - prev < 0.35, f"gap still {p - prev}"


def test_sdca_delta_w_consistent_with_alpha():
    """dw returned by the kernel must equal (1/λn) X^T((a_new − a_old)∘y)."""
    rng = np.random.default_rng(3)
    n, d, lam = 32, 6, 1e-2
    x, y, mask = make_problem(rng, n, d)
    alpha = rng.uniform(0, 1, size=(n, 1)).astype(np.float32)
    w = rng.normal(size=d).astype(np.float32) * 0.05
    s = epoch_seed(5, 0, 0)
    scal = np.array([lam * n, 4.0], np.float32)
    a_new, dw = sdca_epoch(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(alpha),
        jnp.array(w), jnp.array(scal), seed_arr(s), h_steps=2 * n,
    )
    a_new, dw = np.array(a_new), np.array(dw)
    expect = ((a_new - alpha) * y).T @ x / (lam * n)
    assert_allclose(dw, expect[0], rtol=5e-3, atol=5e-5)


# ---------------------------------------------------------------------------
# hinge_stats
# ---------------------------------------------------------------------------

@given(
    n=st.integers(min_value=1, max_value=64),
    d=st.integers(min_value=1, max_value=32),
    wscale=st.sampled_from([0.0, 0.5, 1.5, 3.0]),
    seed=st.integers(min_value=0, max_value=10_000),
)
@settings(max_examples=25, deadline=None)
def test_hinge_matches_reference(n, d, wscale, seed):
    rng = np.random.default_rng(seed)
    x, y, _ = make_problem(rng, n, d)
    wt = rng.uniform(0, 1, size=(n, 1)).astype(np.float32)
    w = (rng.normal(size=d) * wscale).astype(np.float32)
    g_k, s_k = hinge_stats(jnp.array(x), jnp.array(y), jnp.array(wt), jnp.array(w))
    g_r, s_r = hinge_stats_ref(x, y, wt, w)
    assert_allclose(np.array(g_k), g_r, rtol=1e-4, atol=1e-4)
    assert_allclose(np.array(s_k), s_r, rtol=1e-4, atol=1e-4)


def test_hinge_zero_weights_zero_output():
    rng = np.random.default_rng(1)
    x, y, _ = make_problem(rng, 16, 4)
    wt = np.zeros((16, 1), np.float32)
    w = rng.normal(size=4).astype(np.float32)
    g, s = hinge_stats(jnp.array(x), jnp.array(y), jnp.array(wt), jnp.array(w))
    assert np.all(np.array(g) == 0.0) and np.all(np.array(s) == 0.0)


def test_hinge_gradient_is_subgradient():
    """Numerical check: moving against the returned (sub)gradient cannot
    increase the weighted hinge sum (for a small enough step)."""
    rng = np.random.default_rng(2)
    n, d = 32, 6
    x, y, _ = make_problem(rng, n, d)
    wt = np.ones((n, 1), np.float32)
    w = rng.normal(size=d).astype(np.float32)
    g, s = hinge_stats(jnp.array(x), jnp.array(y), jnp.array(wt), jnp.array(w))
    g, h0 = np.array(g), float(np.array(s)[0])
    w2 = w - 1e-4 * g
    _, s2 = hinge_stats(jnp.array(x), jnp.array(y), jnp.array(wt), jnp.array(w2))
    assert float(np.array(s2)[0]) <= h0 + 1e-5


# ---------------------------------------------------------------------------
# pegasos_epoch
# ---------------------------------------------------------------------------

@given(
    n=st.integers(min_value=2, max_value=48),
    d=st.integers(min_value=1, max_value=16),
    lam=st.sampled_from([1e-3, 1e-2, 1e-1]),
    t0=st.integers(min_value=0, max_value=1000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_pegasos_matches_reference(n, d, lam, t0, seed):
    rng = np.random.default_rng(seed % 997)
    x, y, mask = make_problem(rng, n, d)
    w = rng.normal(size=d).astype(np.float32) * 0.1
    s = epoch_seed(seed, 1, 2)
    scal = np.array([lam, float(t0)], np.float32)
    w_k = pegasos_epoch(
        jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(w),
        jnp.array(scal), seed_arr(s), h_steps=n,
    )
    w_r = pegasos_epoch_ref(x, y, mask, w, lam, float(t0), s, n)
    assert_allclose(np.array(w_k), w_r, rtol=2e-4, atol=2e-5)


def test_pegasos_reduces_objective_from_zero():
    rng = np.random.default_rng(4)
    n, d, lam = 128, 8, 1e-2
    x, y, mask = make_problem(rng, n, d)
    w = np.zeros(d, np.float32)
    p0 = primal_objective(x, y, w, lam)
    t0 = 0.0
    for ep in range(10):
        s = epoch_seed(11, ep, 0)
        scal = np.array([lam, t0], np.float32)
        w = np.array(
            pegasos_epoch(
                jnp.array(x), jnp.array(y), jnp.array(mask), jnp.array(w),
                jnp.array(scal), seed_arr(s), h_steps=n,
            )
        )
        t0 += n
    assert primal_objective(x, y, w, lam) < p0

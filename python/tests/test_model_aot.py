"""L2 model + AOT lowering tests: shapes, manifest integrity, and
executability of lowered HLO through jax's own CPU client (the Rust
integration tests re-verify through the `xla` crate's PJRT client)."""

import json
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import kernel_specs, lower_to_hlo_text


@pytest.mark.parametrize("n_loc,d", [(16, 4), (32, 8)])
def test_kernel_specs_shapes(n_loc, d):
    specs = kernel_specs(n_loc, d, h_steps=n_loc)
    assert set(specs) == {"cocoa_local", "grad", "local_sgd"}
    fn, args = specs["cocoa_local"]
    assert args[0].shape == (n_loc, d)
    assert args[4].shape == (d,)
    assert args[6].dtype == jnp.int32


@pytest.mark.parametrize("kernel", ["cocoa_local", "grad", "local_sgd"])
def test_lowering_roundtrips_through_hlo_text_parser(kernel):
    """The interchange contract: the HLO *text* we emit must be parsed
    back by XLA's text parser (this is exactly what the Rust side's
    `HloModuleProto::from_text_file` does) and expose the same entry
    ABI — parameter count, shapes and dtypes — that the manifest
    records. Numeric execution through PJRT is covered by the Rust
    integration tests, which are the real consumer."""
    from jax._src.lib import xla_client as xc

    specs = kernel_specs(8, 4, h_steps=8)
    fn, args = specs[kernel]
    text = lower_to_hlo_text(fn, args)
    assert "HloModule" in text
    assert "ENTRY" in text

    module = xc._xla.hlo_module_from_text(text)
    comp = xc.XlaComputation(module.as_serialized_hlo_module_proto())
    shape = comp.program_shape()
    params = shape.parameter_shapes()
    assert len(params) == len(args)
    for got, want in zip(params, args):
        assert tuple(got.dimensions()) == tuple(want.shape)
        assert np.dtype(got.numpy_dtype()) == want.dtype

    # Outputs are a tuple (return_tuple=True at lowering time); the
    # Rust loader unwraps it. Check arity per kernel.
    result = shape.result_shape()
    n_out = len(result.tuple_shapes()) if result.is_tuple() else 1
    assert n_out == {"cocoa_local": 2, "grad": 2, "local_sgd": 1}[kernel]


def test_aot_cli_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    res = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--n",
            "32",
            "--d",
            "4",
            "--machines",
            "1,2",
        ],
        cwd=Path(__file__).resolve().parents[1],
        capture_output=True,
        text=True,
    )
    assert res.returncode == 0, res.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["n"] == 32
    assert len(manifest["artifacts"]) == 6  # 3 kernels × 2 partition sizes
    for e in manifest["artifacts"]:
        f = out / e["file"]
        assert f.exists()
        assert "HloModule" in f.read_text()[:200]
        assert e["n_loc"] in (32, 16)
        # grad has no epoch loop; others bake h_steps = n_loc
        if e["kernel"] == "grad":
            assert e["h_steps"] == 0
        else:
            assert e["h_steps"] == e["n_loc"]


def test_aot_grid_dedupes_partition_sizes(tmp_path):
    from compile.aot import build_grid

    assert build_grid(8192, [1, 2, 4, 8]) == [8192, 4096, 2048, 1024]
    # Non-dividing machine counts pad upward and dedupe.
    assert build_grid(100, [3, 4]) == [34, 25]
    assert build_grid(64, [64, 32]) == [2, 1]

"""Tests for the LCG shared between JAX kernels and the Rust coordinator."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.lcg import (
    LCG_A,
    LCG_C,
    epoch_seed,
    lcg_index,
    lcg_index_np,
    lcg_next,
    lcg_next_np,
)


def test_known_first_step():
    assert lcg_next_np(np.uint32(1)) == np.uint32(
        (1 * int(LCG_A) + int(LCG_C)) & 0xFFFFFFFF
    )


@given(st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_jax_and_numpy_streams_agree(seed):
    state_np = np.uint32(seed)
    state_jx = jnp.uint32(seed)
    for _ in range(8):
        state_np = lcg_next_np(state_np)
        state_jx = lcg_next(state_jx)
        assert int(state_jx) == int(state_np)


@given(
    st.integers(min_value=0, max_value=2**32 - 1),
    st.integers(min_value=1, max_value=10_000),
)
@settings(max_examples=50, deadline=None)
def test_index_in_range_and_agrees(seed, n):
    state = lcg_next_np(np.uint32(seed))
    i_np = lcg_index_np(state, n)
    i_jx = int(lcg_index(jnp.uint32(int(state)), n))
    assert i_np == i_jx
    assert 0 <= i_np < n


@given(
    st.integers(min_value=0, max_value=2**31 - 1),
    st.integers(min_value=0, max_value=1000),
    st.integers(min_value=0, max_value=512),
)
@settings(max_examples=50, deadline=None)
def test_epoch_seed_no_overflow_and_nonzero(seed, epoch, part):
    s = epoch_seed(seed, epoch, part)
    assert s != 0
    assert 0 < int(s) < 2**32


def test_epoch_seed_distinguishes_partitions():
    seeds = {int(epoch_seed(1, 5, p)) for p in range(128)}
    assert len(seeds) == 128


def test_index_distribution_roughly_uniform():
    state = np.uint32(12345)
    counts = np.zeros(16)
    for _ in range(16_000):
        state = lcg_next_np(state)
        counts[lcg_index_np(state, 16)] += 1
    # every bucket within ±30% of expectation
    assert counts.min() > 700 and counts.max() < 1300


def test_seed_bitcast_roundtrip_through_int32():
    # The artifact ABI carries the seed as i32; make sure u32 seeds with
    # the high bit set survive the bitcast the kernels perform.
    s = np.uint32(0xDEADBEEF)
    as_i32 = np.int32(s.view(np.int32))
    back = jax.lax.bitcast_convert_type(jnp.int32(as_i32), jnp.uint32)
    assert int(back) == int(s)

"""The lax mirrors must be step-identical to the canonical Pallas
kernels: same LCG stream, same update order, same arithmetic. These
tests pin the CPU production artifacts to the Pallas semantics."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st
from numpy.testing import assert_allclose

from compile.kernels import pegasos_epoch, sdca_epoch
from compile.kernels.lax_mirrors import pegasos_epoch_lax, sdca_epoch_lax
from compile.kernels.lcg import epoch_seed


def seed_arr(s):
    return jnp.array([np.int32(np.uint32(s).view(np.int32))])


def problem(rng, n, d):
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = np.sign(rng.normal(size=(n, 1))).astype(np.float32)
    y[y == 0] = 1.0
    mask = np.ones((n, 1), np.float32)
    return jnp.array(x), jnp.array(y), jnp.array(mask)


@given(
    n=st.integers(min_value=2, max_value=64),
    d=st.integers(min_value=1, max_value=32),
    sigma=st.sampled_from([1.0, 4.0, 16.0]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_sdca_mirror_matches_pallas(n, d, sigma, seed):
    rng = np.random.default_rng(seed % 991)
    x, y, mask = problem(rng, n, d)
    alpha = jnp.array(rng.uniform(0, 1, size=(n, 1)).astype(np.float32))
    w = jnp.array((rng.normal(size=d) * 0.1).astype(np.float32))
    scal = jnp.array([0.01 * n, sigma], jnp.float32)
    s = seed_arr(epoch_seed(seed, 1, 2))
    h = 2 * n
    a_p, dw_p = sdca_epoch(x, y, mask, alpha, w, scal, s, h_steps=h)
    a_l, dw_l = sdca_epoch_lax(x, y, mask, alpha, w, scal, s, h_steps=h)
    assert_allclose(np.array(a_p), np.array(a_l), rtol=1e-6, atol=1e-7)
    assert_allclose(np.array(dw_p), np.array(dw_l), rtol=1e-5, atol=1e-6)


@given(
    n=st.integers(min_value=2, max_value=64),
    d=st.integers(min_value=1, max_value=32),
    lam=st.sampled_from([1e-4, 1e-2]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_pegasos_mirror_matches_pallas(n, d, lam, seed):
    rng = np.random.default_rng(seed % 983)
    x, y, mask = problem(rng, n, d)
    w = jnp.array((rng.normal(size=d) * 0.1).astype(np.float32))
    scal = jnp.array([lam, 10.0], jnp.float32)
    s = seed_arr(epoch_seed(seed, 3, 4))
    w_p = pegasos_epoch(x, y, mask, w, scal, s, h_steps=n)
    w_l = pegasos_epoch_lax(x, y, mask, w, scal, s, h_steps=n)
    assert_allclose(np.array(w_p), np.array(w_l), rtol=1e-5, atol=1e-6)


def test_mirror_respects_padding():
    rng = np.random.default_rng(7)
    n, d = 32, 8
    x, y, mask = problem(rng, n, d)
    mask = mask.at[5:9].set(0.0)
    alpha = jnp.zeros((n, 1), jnp.float32)
    w = jnp.zeros(d, jnp.float32)
    scal = jnp.array([0.32, 1.0], jnp.float32)
    s = seed_arr(epoch_seed(1, 1, 1))
    a_l, _ = sdca_epoch_lax(x, y, mask, alpha, w, scal, s, h_steps=4 * n)
    a_l = np.array(a_l)
    assert np.all(a_l[5:9] == 0.0)


def test_lax_artifact_lowering_has_while_loop():
    """The lax mirror must lower to a single fused while loop (the
    whole point of the optimization)."""
    from compile.model import kernel_specs, lower_to_hlo_text

    fn, args = kernel_specs(64, 8, 64, impl="lax")["cocoa_local"]
    text = lower_to_hlo_text(fn, args)
    assert "while" in text
    # And parameter ABI is unchanged vs the pallas variant.
    fn_p, args_p = kernel_specs(64, 8, 64, impl="pallas")["cocoa_local"]
    assert len(args) == len(args_p)

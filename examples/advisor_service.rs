//! Fit-once / query-many: the advisor as a service.
//!
//! First invocation pays the full cost (sweep + profiling + model
//! fits) and persists the artifacts under `<out_dir>/models/`; every
//! later invocation — and every query inside one — answers from the
//! loaded models in microseconds. This is the paper's §3.1 interface
//! turned into an actual serving surface (`hemingway serve` wires the
//! same registry to stdin/stdout).
//!
//! ```bash
//! cargo run --release --example advisor_service
//! ```

use std::time::Instant;

use hemingway::advisor::{handle_line, AlgorithmId, Constraints, Query};
use hemingway::config::ExperimentConfig;
use hemingway::repro::common::load_or_fit_registry;

fn main() -> hemingway::Result<()> {
    hemingway::util::logger::init_from_env();
    let cfg = ExperimentConfig {
        n: 2048,
        d: 64,
        machines: vec![1, 2, 4, 8, 16, 32],
        max_iters: 200,
        out_dir: std::env::temp_dir()
            .join("hemingway_advisor_service")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    };
    let algos = [AlgorithmId::CocoaPlus, AlgorithmId::Cocoa];

    // ---- Fit once (or load the persisted artifacts) ----
    let t0 = Instant::now();
    let registry = load_or_fit_registry(&cfg, true, &algos)?;
    println!(
        "registry ready: {} models in {:.2}s (second run loads artifacts and takes milliseconds)",
        registry.len(),
        t0.elapsed().as_secs_f64()
    );

    // ---- Query many ----
    let t1 = Instant::now();
    let mut answered = 0usize;
    for k in 0..500 {
        let eps = 10f64.powf(-2.0 - 2.0 * (k as f64 / 499.0)); // 1e-2 … 1e-4
        if registry.answer(&Query::fastest_to(eps)).is_some() {
            answered += 1;
        }
        if registry.answer(&Query::best_at(1.0 + k as f64 / 10.0)).is_some() {
            answered += 1;
        }
    }
    let elapsed = t1.elapsed().as_secs_f64();
    println!(
        "answered {answered} queries in {:.3}s ({:.1} µs/query) — no sweep re-run",
        elapsed,
        1e6 * elapsed / answered.max(1) as f64
    );

    // ---- Typed answers, including constrained variants ----
    if let Some(rec) = registry.answer(&Query::fastest_to(cfg.target_subopt)) {
        println!(
            "fastest to {:.0e}:          {} m={} → {:.2} predicted seconds",
            cfg.target_subopt,
            rec.algorithm,
            rec.machines,
            rec.predicted.value()
        );
    }
    let capped = Query::fastest_to(cfg.target_subopt).with(Constraints {
        max_machines: Some(4),
        ..Constraints::none()
    });
    if let Some(rec) = registry.answer(&capped) {
        println!(
            "… with at most 4 machines: {} m={} → {:.2} predicted seconds",
            rec.algorithm,
            rec.machines,
            rec.predicted.value()
        );
    }
    if let Some(rec) = registry.answer(&Query::best_at(20.0)) {
        println!(
            "best loss in 20s:          {} m={} → {:.2e} predicted suboptimality",
            rec.algorithm,
            rec.machines,
            rec.predicted.value()
        );
    }

    // ---- The serve wire format, without a process boundary ----
    for line in [
        r#"{"query":"fastest_to","eps":1e-3,"machine_cost_weight":0.05}"#,
        r#"{"query":"models"}"#,
    ] {
        println!("→ {line}");
        println!("← {}", handle_line(&registry, line));
    }
    Ok(())
}

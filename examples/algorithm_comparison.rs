//! Algorithm comparison (the Fig 1(c) scenario as an API example):
//! run all five algorithms at the same parallelism and compare both
//! iteration-domain and time-domain convergence.
//!
//! ```bash
//! make artifacts && cargo run --release --example algorithm_comparison
//! ```

use hemingway::cluster::BspSim;
use hemingway::config::ExperimentConfig;
use hemingway::optim::{by_name, run, RunConfig, ALL_ALGORITHMS};
use hemingway::repro::ReproContext;
use hemingway::util::asciiplot::{plot, PlotCfg, Series};

fn main() -> hemingway::Result<()> {
    hemingway::util::logger::init_from_env();
    let cfg = ExperimentConfig {
        n: 2048,
        machines: vec![16],
        max_iters: 200,
        ..Default::default()
    };
    let ctx = ReproContext::new_with_fallback(cfg)?;
    let backend = ctx.backend();
    let m = 16;

    let mut series = Vec::new();
    println!(
        "algorithm comparison at m={m} ({} path):\n",
        if ctx.use_native { "native" } else { "HLO" }
    );
    println!(
        "{:<15} {:>10} {:>12} {:>12} {:>12}",
        "algorithm", "iters", "subopt@50", "final", "sim time"
    );
    for name in ALL_ALGORITHMS {
        let mut algo = by_name(name, &ctx.problem, m, 42)?;
        let mut sim = BspSim::new(ctx.profile.clone(), 42);
        let trace = run(
            algo.as_mut(),
            backend.as_ref(),
            &ctx.problem,
            &mut sim,
            ctx.p_star,
            &RunConfig {
                max_iters: 200,
                target_subopt: 1e-4,
                time_budget: None,
            },
        )?;
        let at50 = trace
            .records
            .iter()
            .find(|r| r.iter == 50)
            .map(|r| r.subopt)
            .unwrap_or(trace.final_subopt());
        println!(
            "{:<15} {:>10} {:>12.3e} {:>12.3e} {:>10.1}s",
            name,
            trace.records.last().unwrap().iter,
            at50,
            trace.final_subopt(),
            trace.records.last().unwrap().sim_time
        );
        series.push(Series::new(
            *name,
            trace
                .records
                .iter()
                .filter(|r| r.iter >= 1 && r.subopt > 0.0)
                .map(|r| (r.iter as f64, r.subopt))
                .collect(),
        ));
    }
    println!(
        "\n{}",
        plot(
            &series,
            &PlotCfg {
                title: format!("suboptimality vs iteration at m={m} (log y)"),
                log_y: true,
                x_label: "iteration".into(),
                ..Default::default()
            }
        )
    );
    Ok(())
}

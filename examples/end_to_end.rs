//! End-to-end driver (the EXPERIMENTS.md run): exercises the FULL
//! system on the paper's default workload through the production
//! path — AOT Pallas kernels via PJRT on every per-partition call,
//! simulated BSP cluster for time, both models fitted, the advisor
//! queried, and the adaptive loop executed. Prints a compact report.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use hemingway::advisor::{
    adaptive_cocoa_plus, AdaptiveConfig, AlgorithmId, CombinedModel, ModelKey, ModelRegistry,
    Query,
};
use hemingway::cluster::BspSim;
use hemingway::config::ExperimentConfig;
use hemingway::hemingway_model::{points_from_traces, ConvergenceModel, FeatureLibrary};
use hemingway::repro::ReproContext;

fn main() -> hemingway::Result<()> {
    hemingway::util::logger::init_from_env();
    let t_start = std::time::Instant::now();

    // The paper's protocol: n=8192×128 MNIST-like, hinge SVM,
    // m ∈ {1..128}, stop at 1e-4 or 500 iterations. HLO backend.
    let cfg = ExperimentConfig::default();
    let ctx = ReproContext::new_with_fallback(cfg)?;

    // ---- Phase 1: the measurement sweep (all through PJRT) ----
    println!("\n=== Phase 1: CoCoA+ sweep over m (production HLO path) ===");
    let traces = ctx.run_sweep("cocoa+")?;
    for t in &traces.traces {
        println!(
            "  m={:<4} iters-to-1e-4 {:<6} mean f(m) {:.4}s  final subopt {:.2e}",
            t.machines,
            t.iters_to(1e-4).map(|i| i.to_string()).unwrap_or("-".into()),
            t.mean_iter_time(),
            t.final_subopt()
        );
    }

    // ---- Phase 2: fit both models ----
    println!("\n=== Phase 2: model fitting ===");
    let conv = ConvergenceModel::fit(
        &points_from_traces(&traces.traces),
        FeatureLibrary::standard(),
        1,
    )?;
    println!(
        "  convergence model R² = {:.4} on {} points",
        conv.train_r2, conv.n_train
    );
    for (name, coef) in conv.selected_features() {
        println!("    {name:<22} {coef:+.5}");
    }
    let ernest = ctx.fit_ernest("cocoa+")?;
    println!(
        "  Ernest: f(m) = {:.4} + {:.3e}(size/m) + {:.4} log m + {:.5} m",
        ernest.theta[0], ernest.theta[1], ernest.theta[2], ernest.theta[3]
    );

    // ---- Phase 3: advisor queries (typed API over the registry) ----
    println!("\n=== Phase 3: advisor ===");
    let combined = CombinedModel::new(ernest, conv, ctx.problem.data.n as f64);
    let mut registry =
        ModelRegistry::new(ctx.cfg.machines.clone(), ctx.cfg.advisor_iter_cap);
    registry.insert(
        ModelKey {
            algorithm: AlgorithmId::CocoaPlus,
            context: ctx.cfg.model_context_hash(ctx.use_native),
        },
        combined,
    );
    if let Some(rec) = registry.answer(&Query::fastest_to(1e-4)) {
        println!(
            "  fastest to 1e-4:   {} m={} (predicted {:.1}s)",
            rec.algorithm,
            rec.machines,
            rec.predicted.value()
        );
    }
    if let Some(rec) = registry.answer(&Query::best_at(30.0)) {
        println!(
            "  best loss in 30s:  {} m={} (predicted {:.2e})",
            rec.algorithm,
            rec.machines,
            rec.predicted.value()
        );
    }

    // ---- Phase 4: the Fig 2 adaptive loop ----
    println!("\n=== Phase 4: adaptive reconfiguration (Fig 2) ===");
    let backend = ctx.backend();
    let mut sim = BspSim::new(ctx.profile.clone(), 99);
    let run = adaptive_cocoa_plus(
        &ctx.problem,
        backend.as_ref(),
        &mut sim,
        ctx.p_star,
        &AdaptiveConfig {
            seed: 9,
            ..AdaptiveConfig::from_experiment(&ctx.cfg, 10.0, 8)
        },
    )?;
    for f in &run.frames {
        println!(
            "  frame {} m={:<4} iters={:<4} subopt {:.2e} → {:.2e}{}",
            f.frame,
            f.machines,
            f.iterations,
            f.start_subopt,
            f.end_subopt,
            if f.model_driven { "  [model-driven]" } else { "" }
        );
    }
    println!(
        "  adaptive: final subopt {:.2e} in {:.1}s simulated",
        run.final_subopt, run.total_time
    );

    println!(
        "\nend_to_end complete in {:.1}s wall-clock (per-partition compute via {})",
        t_start.elapsed().as_secs_f64(),
        if ctx.use_native { "the native mirror" } else { "PJRT" }
    );
    Ok(())
}

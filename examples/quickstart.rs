//! Quickstart: the 5-minute tour of the public API.
//!
//! Runs CoCoA+ through the production AOT/PJRT path on a small
//! MNIST-like problem, prints the convergence trace, then fits both
//! Hemingway models and asks the advisor a question.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use hemingway::cluster::{BspSim, HardwareProfile};
use hemingway::config::ExperimentConfig;
use hemingway::data::synth::mnist_like;
use hemingway::ernest::ErnestModel;
use hemingway::hemingway_model::{points_from_traces, ConvergenceModel, FeatureLibrary};
use hemingway::optim::{run, Backend, Cocoa, CocoaVariant, HloBackend, NativeBackend, Problem, RunConfig};
use hemingway::runtime::{default_artifact_dir, Engine};

fn main() -> hemingway::Result<()> {
    hemingway::util::logger::init_from_env();

    // 1. A small problem (1024 rows stay inside the default artifact
    //    grid: every n/m here is a power of two ≥ 64).
    let cfg = ExperimentConfig {
        n: 1024,
        machines: vec![1, 2, 4, 8, 16],
        ..Default::default()
    };
    let data = mnist_like(&cfg.synth());
    let problem = Problem::new(data, cfg.lambda);
    let (p_star, _, gap) = problem.reference_solve(1e-7, 500);
    println!("reference optimum P* = {p_star:.6} (gap {gap:.1e})");

    // 2. The production backend: AOT-compiled Pallas kernels via PJRT.
    //    Falls back to the numerically-equivalent native mirror when
    //    the PJRT path is unavailable (no `pjrt` feature / artifacts).
    let engine = Engine::new(&default_artifact_dir());
    let backend: Box<dyn Backend + '_> = match &engine {
        Ok(e) => Box::new(HloBackend::new(e)),
        Err(e) => {
            eprintln!("PJRT path unavailable ({e}); using the native backend");
            Box::new(NativeBackend)
        }
    };

    // 3. Run CoCoA+ on 4 simulated machines.
    let mut algo = Cocoa::new(&problem, 4, CocoaVariant::Adding, 42);
    let mut sim = BspSim::new(HardwareProfile::local48(), 42);
    let trace = run(
        &mut algo,
        backend.as_ref(),
        &problem,
        &mut sim,
        p_star,
        &RunConfig::default(),
    )?;
    println!("\nCoCoA+ m=4 convergence:");
    for r in trace.records.iter().step_by(4).take(12) {
        println!(
            "  iter {:>3}  t={:>6.2}s  subopt {:.3e}",
            r.iter, r.sim_time, r.subopt
        );
    }

    // 4. Fit g(i, m) from a quick sweep and f(m) from the same traces.
    let mut traces = vec![trace];
    for m in [1usize, 2, 8, 16] {
        let mut a = Cocoa::new(&problem, m, CocoaVariant::Adding, 42);
        let mut s = BspSim::new(HardwareProfile::local48(), 7 + m as u64);
        traces.push(run(&mut a, backend.as_ref(), &problem, &mut s, p_star, &RunConfig::default())?);
    }
    let conv = ConvergenceModel::fit(
        &points_from_traces(&traces),
        FeatureLibrary::standard(),
        1,
    )?;
    println!(
        "\nconvergence model: R² = {:.4}; selected features: {:?}",
        conv.train_r2,
        conv.selected_features()
            .iter()
            .map(|(n, _)| *n)
            .collect::<Vec<_>>()
    );

    let obs: Vec<hemingway::ernest::Observation> = traces
        .iter()
        .flat_map(|t| {
            t.records.windows(2).map(|w| hemingway::ernest::Observation {
                machines: t.machines,
                size: problem.data.n as f64,
                time: w[1].sim_time - w[0].sim_time,
            })
        })
        .collect();
    let ernest = ErnestModel::fit(&obs)?;
    println!(
        "system model: f(m) = {:.3} + {:.2e}(size/m) + {:.3}·log m + {:.4}·m",
        ernest.theta[0], ernest.theta[1], ernest.theta[2], ernest.theta[3]
    );

    // 5. Ask the combined model a question.
    let combined =
        hemingway::advisor::CombinedModel::new(ernest, conv, problem.data.n as f64);
    println!("\npredicted time to 1e-3 suboptimality:");
    for m in [1usize, 2, 4, 8, 16] {
        match combined.time_to_subopt(1e-3, m, 10_000) {
            Some(t) => println!("  m={m:<3} {t:>7.2}s"),
            None => println!("  m={m:<3} (not reached)"),
        }
    }
    Ok(())
}

//! The Fig 2 idealized loop as a standalone demo: per time frame,
//! Hemingway refits Θ (system) and Λ (convergence) from everything
//! observed so far and re-chooses the degree of parallelism; CoCoA+'s
//! per-row dual state is exactly repartitioned in place.
//!
//! Compares the adaptive run against the best *fixed* configuration to
//! show when reconfiguration wins (paper §6 "Adaptive algorithms").
//!
//! ```bash
//! make artifacts && cargo run --release --example adaptive_advisor
//! ```

use hemingway::advisor::{adaptive_cocoa_plus, AdaptiveConfig};
use hemingway::cluster::BspSim;
use hemingway::config::ExperimentConfig;
use hemingway::optim::{run, Cocoa, CocoaVariant, RunConfig};
use hemingway::repro::ReproContext;

fn main() -> hemingway::Result<()> {
    hemingway::util::logger::init_from_env();
    let cfg = ExperimentConfig {
        n: 4096,
        machines: vec![1, 2, 4, 8, 16, 32, 64],
        ..Default::default()
    };
    let ctx = ReproContext::new_with_fallback(cfg)?;
    let backend = ctx.backend();

    // ---- Adaptive run ----
    let mut sim = BspSim::new(ctx.profile.clone(), 5);
    let adaptive = adaptive_cocoa_plus(
        &ctx.problem,
        backend.as_ref(),
        &mut sim,
        ctx.p_star,
        &AdaptiveConfig {
            bootstrap_machines: 32,
            seed: 5,
            ..AdaptiveConfig::from_experiment(&ctx.cfg, 8.0, 10)
        },
    )?;
    println!("adaptive CoCoA+ (reconfigures m each frame):");
    for f in &adaptive.frames {
        println!(
            "  frame {} m={:<4} iters={:<4} subopt {:.2e} → {:.2e} (t={:>6.1}s){}",
            f.frame,
            f.machines,
            f.iterations,
            f.start_subopt,
            f.end_subopt,
            f.sim_time_end,
            if f.model_driven { " [model-driven]" } else { " [bootstrap]" }
        );
    }
    println!(
        "  → {:.2e} suboptimality in {:.1}s\n",
        adaptive.final_subopt, adaptive.total_time
    );

    // ---- Fixed-m baselines under the same time budget ----
    println!("fixed configurations, same time budget:");
    let budget = adaptive.total_time;
    let mut best_fixed = f64::INFINITY;
    for &m in &ctx.cfg.machines {
        let mut algo = Cocoa::new(&ctx.problem, m, CocoaVariant::Adding, 5);
        let mut sim = BspSim::new(ctx.profile.clone(), 5);
        let trace = run(
            &mut algo,
            backend.as_ref(),
            &ctx.problem,
            &mut sim,
            ctx.p_star,
            &RunConfig {
                max_iters: 100_000,
                target_subopt: 0.0,
                time_budget: Some(budget),
            },
        )?;
        let s = trace.final_subopt();
        best_fixed = best_fixed.min(s);
        println!("  fixed m={m:<4} → subopt {s:.2e}");
    }
    println!(
        "\nadaptive {:.2e} vs best fixed {:.2e} → {}",
        adaptive.final_subopt,
        best_fixed,
        if adaptive.final_subopt <= best_fixed * 1.5 {
            "adaptive is competitive with the best fixed config (chosen without knowing it!)"
        } else {
            "fixed wins here — see EXPERIMENTS.md discussion"
        }
    );
    Ok(())
}
